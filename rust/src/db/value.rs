//! Runtime values, rows and keys.

use crate::catalog::ValueType;
use crate::sqlir::{CmpOp, Literal, Scalar};
use std::cmp::Ordering;
use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};

/// A runtime value. `Float` is hashable/orderable via its bit pattern
/// after normalizing `-0.0` and NaN, so values can serve as map keys.
#[derive(Debug)]
pub enum Value {
    /// 64-bit integer.
    Int(i64),
    /// 64-bit float (bit-pattern hashable, see type docs).
    Float(f64),
    /// UTF-8 string.
    Str(String),
    /// SQL NULL: never compares equal, propagates through arithmetic.
    Null,
}

#[cfg(debug_assertions)]
thread_local! {
    /// Per-thread count of `Value::clone` calls (debug builds only) —
    /// the instrumentation behind the allocation-free read-path tests.
    static VALUE_CLONES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Number of [`Value`] clones this thread has performed so far, or
/// `None` when the counter is compiled out (release builds). Tests take
/// a before/after delta around an operation to assert the read path
/// clones no values (`rust/tests/prepared_equivalence.rs`); the counter
/// is monotone and never reset.
pub fn value_clone_count() -> Option<u64> {
    #[cfg(debug_assertions)]
    {
        Some(VALUE_CLONES.with(|c| c.get()))
    }
    #[cfg(not(debug_assertions))]
    {
        None
    }
}

impl Clone for Value {
    /// Identical to the derived impl, plus a debug-only thread-local
    /// counter bump so tests can assert clone-freedom (zero overhead in
    /// release builds).
    fn clone(&self) -> Value {
        #[cfg(debug_assertions)]
        VALUE_CLONES.with(|c| c.set(c.get() + 1));
        match self {
            Value::Int(i) => Value::Int(*i),
            Value::Float(x) => Value::Float(*x),
            Value::Str(s) => Value::Str(s.clone()),
            Value::Null => Value::Null,
        }
    }
}

impl Value {
    /// Short type label for error messages.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Int(_) => "int",
            Value::Float(_) => "float",
            Value::Str(_) => "str",
            Value::Null => "null",
        }
    }

    /// Convert a parsed SQL literal into a runtime value.
    pub fn from_literal(lit: &Literal) -> Value {
        match lit {
            Literal::Int(i) => Value::Int(*i),
            Literal::Float(x) => Value::Float(*x),
            Literal::Str(s) => Value::Str(s.clone()),
            Literal::Null => Value::Null,
        }
    }

    /// Coerce into a declared column type (ints widen to floats, anything
    /// renders to string for Str columns). Null passes through.
    pub fn coerce(self, ty: ValueType) -> Value {
        match (self, ty) {
            (Value::Null, _) => Value::Null,
            (Value::Int(i), ValueType::Float) => Value::Float(i as f64),
            (Value::Float(x), ValueType::Int) => Value::Int(x.round() as i64),
            (v @ Value::Int(_), ValueType::Int) => v,
            (v @ Value::Float(_), ValueType::Float) => v,
            (v @ Value::Str(_), ValueType::Str) => v,
            (Value::Int(i), ValueType::Str) => Value::Str(i.to_string()),
            (Value::Float(x), ValueType::Str) => Value::Str(x.to_string()),
            (Value::Str(s), ValueType::Int) => {
                Value::Int(s.parse().unwrap_or_else(|_| panic!("cannot coerce {s:?} to int")))
            }
            (Value::Str(s), ValueType::Float) => {
                Value::Float(s.parse().unwrap_or_else(|_| panic!("cannot coerce {s:?} to float")))
            }
        }
    }

    /// The value as an integer (floats truncate), or `None` for
    /// non-numeric values.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(x) => Some(*x as i64),
            _ => None,
        }
    }

    /// The value as a float (ints widen), or `None` for non-numeric
    /// values.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as a borrowed string, or `None` for non-string values.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    fn norm_bits(x: f64) -> u64 {
        if x.is_nan() {
            f64::NAN.to_bits()
        } else if x == 0.0 {
            0u64 // normalize -0.0
        } else {
            x.to_bits()
        }
    }

    /// Total comparison used by ORDER BY and range predicates. Numeric
    /// types compare numerically against each other; Null sorts first;
    /// cross-type (number vs string) compares by type rank — predicates on
    /// typed columns never hit that case.
    pub fn total_cmp(&self, other: &Value) -> Ordering {
        use Value::*;
        match (self, other) {
            (Null, Null) => Ordering::Equal,
            (Null, _) => Ordering::Less,
            (_, Null) => Ordering::Greater,
            (Int(a), Int(b)) => a.cmp(b),
            (Str(a), Str(b)) => a.cmp(b),
            (a, b) => match (a.as_f64(), b.as_f64()) {
                (Some(x), Some(y)) => x.partial_cmp(&y).unwrap_or(Ordering::Equal),
                _ => a.type_rank().cmp(&b.type_rank()),
            },
        }
    }

    fn type_rank(&self) -> u8 {
        match self {
            Value::Null => 0,
            Value::Int(_) | Value::Float(_) => 1,
            Value::Str(_) => 2,
        }
    }

    /// SQL comparison semantics: any comparison involving NULL is false.
    pub fn sql_cmp(&self, op: CmpOp, other: &Value) -> bool {
        if matches!(self, Value::Null) || matches!(other, Value::Null) {
            return false;
        }
        let ord = self.total_cmp(other);
        match op {
            CmpOp::Eq => ord == Ordering::Equal,
            CmpOp::Ne => ord != Ordering::Equal,
            CmpOp::Lt => ord == Ordering::Less,
            CmpOp::Le => ord != Ordering::Greater,
            CmpOp::Gt => ord == Ordering::Greater,
            CmpOp::Ge => ord != Ordering::Less,
        }
    }
}

impl PartialEq for Value {
    fn eq(&self, other: &Self) -> bool {
        use Value::*;
        match (self, other) {
            (Null, Null) => true,
            (Int(a), Int(b)) => a == b,
            (Str(a), Str(b)) => a == b,
            (Float(a), Float(b)) => Value::norm_bits(*a) == Value::norm_bits(*b),
            // Int/Float cross-equality so `WHERE price = 10` matches 10.0.
            (Int(a), Float(b)) | (Float(b), Int(a)) => (*a as f64) == *b,
            _ => false,
        }
    }
}

impl Eq for Value {}

impl Hash for Value {
    fn hash<H: Hasher>(&self, state: &mut H) {
        match self {
            Value::Null => 0u8.hash(state),
            // Ints and integral floats must hash identically to honor the
            // cross-type Eq above.
            Value::Int(i) => {
                1u8.hash(state);
                Value::norm_bits(*i as f64).hash(state);
            }
            Value::Float(x) => {
                1u8.hash(state);
                Value::norm_bits(*x).hash(state);
            }
            Value::Str(s) => {
                2u8.hash(state);
                s.hash(state);
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "{s}"),
            Value::Null => write!(f, "NULL"),
        }
    }
}

/// A row: values in the table's column order.
pub type Row = Vec<Value>;

/// A primary-key value tuple.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Key(
    /// Key values in primary-key column order.
    pub Vec<Value>,
);

impl Key {
    /// A single-column key.
    pub fn single(v: Value) -> Key {
        Key(vec![v])
    }

    /// Deterministic 64-bit hash used to address row locks by value
    /// instead of by cloned key (see [`crate::db::lockmgr::LockTarget`]).
    /// A collision merges two lock targets, which is safe: coarser
    /// locking can only add blocking, never remove it.
    pub fn lock_hash(&self) -> u64 {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.0.len().hash(&mut h);
        self.hash(&mut h);
        h.finish()
    }
}

impl fmt::Display for Key {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let parts: Vec<String> = self.0.iter().map(|v| v.to_string()).collect();
        write!(f, "({})", parts.join(","))
    }
}

/// Name-keyed parameter bindings. This is the *convenience* form used by
/// tests, examples and transaction bodies; the engine's hot path resolves
/// names to integer slots once at prepare time (see
/// [`crate::db::prepared::BindSlots`]).
pub type Bindings = HashMap<String, Value>;

/// Evaluate a [`Scalar`] given the current row (for `Col` references) and
/// parameter bindings. `row`/`col_of` may be absent when evaluating
/// row-independent scalars (INSERT values).
pub fn eval_scalar(
    scalar: &Scalar,
    row: Option<&Row>,
    col_of: &dyn Fn(&str) -> Option<usize>,
    binds: &Bindings,
) -> Result<Value, String> {
    match scalar {
        Scalar::Lit(l) => Ok(Value::from_literal(l)),
        Scalar::Param(p) => {
            binds.get(p).cloned().ok_or_else(|| format!("unbound parameter ?{p}"))
        }
        Scalar::Col(c) => {
            let row = row.ok_or_else(|| format!("column {c} referenced in row-free context"))?;
            let idx = col_of(c).ok_or_else(|| format!("unknown column {c}"))?;
            Ok(row[idx].clone())
        }
        Scalar::Add(a, b) | Scalar::Sub(a, b) | Scalar::Mul(a, b) => {
            let va = eval_scalar(a, row, col_of, binds)?;
            let vb = eval_scalar(b, row, col_of, binds)?;
            let kind = match scalar {
                Scalar::Add(..) => ArithKind::Add,
                Scalar::Sub(..) => ArithKind::Sub,
                _ => ArithKind::Mul,
            };
            numeric_arith(kind, &va, &vb)
        }
    }
}

/// Arithmetic operator kinds shared by the interpreted ([`eval_scalar`])
/// and compiled ([`crate::db::prepared`]) evaluators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArithKind {
    /// Addition.
    Add,
    /// Subtraction.
    Sub,
    /// Multiplication.
    Mul,
}

/// SQL arithmetic: NULL propagates, integer ops stay integer, anything
/// else goes through f64. Integer overflow saturates (with a debug
/// assertion) rather than wrapping — the same contract as
/// [`crate::db::update::ColOp::apply`], which re-derives these results
/// on replicas; the two must agree bit-for-bit or replay diverges.
pub fn numeric_arith(kind: ArithKind, a: &Value, b: &Value) -> Result<Value, String> {
    if matches!(a, Value::Null) || matches!(b, Value::Null) {
        return Ok(Value::Null);
    }
    if let (Value::Int(x), Value::Int(y)) = (a, b) {
        let (checked, saturated) = match kind {
            ArithKind::Add => (x.checked_add(*y), x.saturating_add(*y)),
            ArithKind::Sub => (x.checked_sub(*y), x.saturating_sub(*y)),
            ArithKind::Mul => (x.checked_mul(*y), x.saturating_mul(*y)),
        };
        debug_assert!(
            checked.is_some(),
            "integer arithmetic overflows: {x} {kind:?} {y} (saturating in release)"
        );
        return Ok(Value::Int(checked.unwrap_or(saturated)));
    }
    let (x, y) = match (a.as_f64(), b.as_f64()) {
        (Some(x), Some(y)) => (x, y),
        _ => return Err(format!("arithmetic on non-numeric values {a} and {b}")),
    };
    let r = match kind {
        ArithKind::Add => x + y,
        ArithKind::Sub => x - y,
        ArithKind::Mul => x * y,
    };
    Ok(Value::Float(r))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::hash_map::DefaultHasher;

    fn h(v: &Value) -> u64 {
        let mut s = DefaultHasher::new();
        v.hash(&mut s);
        s.finish()
    }

    #[test]
    fn clone_counter_counts_in_debug_builds() {
        if let Some(before) = value_clone_count() {
            let v = Value::Str("x".into());
            let _copies = [v.clone(), v.clone()];
            assert_eq!(value_clone_count().unwrap(), before + 2);
        }
    }

    #[test]
    fn int_float_cross_equality_and_hash() {
        assert_eq!(Value::Int(3), Value::Float(3.0));
        assert_eq!(h(&Value::Int(3)), h(&Value::Float(3.0)));
        assert_ne!(Value::Int(3), Value::Float(3.5));
        assert_eq!(Value::Float(0.0), Value::Float(-0.0));
        assert_eq!(h(&Value::Float(0.0)), h(&Value::Float(-0.0)));
    }

    #[test]
    fn null_never_compares_true() {
        assert!(!Value::Null.sql_cmp(CmpOp::Eq, &Value::Null));
        assert!(!Value::Int(1).sql_cmp(CmpOp::Eq, &Value::Null));
        assert!(!Value::Null.sql_cmp(CmpOp::Ne, &Value::Int(1)));
    }

    #[test]
    fn sql_cmp_semantics() {
        assert!(Value::Int(2).sql_cmp(CmpOp::Lt, &Value::Int(3)));
        assert!(Value::Int(2).sql_cmp(CmpOp::Le, &Value::Float(2.0)));
        assert!(Value::Str("b".into()).sql_cmp(CmpOp::Gt, &Value::Str("a".into())));
        assert!(Value::Float(1.5).sql_cmp(CmpOp::Ne, &Value::Int(1)));
    }

    #[test]
    fn coercion_into_column_types() {
        assert_eq!(Value::Int(3).coerce(ValueType::Float), Value::Float(3.0));
        assert_eq!(Value::Str("12".into()).coerce(ValueType::Int), Value::Int(12));
        assert_eq!(Value::Int(7).coerce(ValueType::Str), Value::Str("7".into()));
        assert_eq!(Value::Null.coerce(ValueType::Int), Value::Null);
    }

    #[test]
    fn eval_scalar_arithmetic() {
        let binds: Bindings = [("q".to_string(), Value::Int(4))].into_iter().collect();
        let row: Row = vec![Value::Int(10)];
        let col_of = |c: &str| if c == "STOCK" { Some(0) } else { None };
        let expr = Scalar::Sub(
            Box::new(Scalar::Col("STOCK".into())),
            Box::new(Scalar::Param("q".into())),
        );
        let v = eval_scalar(&expr, Some(&row), &col_of, &binds).unwrap();
        assert_eq!(v, Value::Int(6));
    }

    #[test]
    fn eval_scalar_unbound_param_errors() {
        let binds = Bindings::new();
        let err = eval_scalar(&Scalar::Param("x".into()), None, &|_| None, &binds).unwrap_err();
        assert!(err.contains("unbound"));
    }

    #[test]
    fn arithmetic_with_null_is_null() {
        let binds = Bindings::new();
        let expr = Scalar::Add(
            Box::new(Scalar::Lit(Literal::Null)),
            Box::new(Scalar::Lit(Literal::Int(1))),
        );
        assert_eq!(eval_scalar(&expr, None, &|_| None, &binds).unwrap(), Value::Null);
    }
}
