//! Borrowed result materialization: the allocation-free read path.
//!
//! The engine used to materialize every SELECT into an owned
//! `QueryResult { rows: Vec<Vec<Value>> }`, cloning each projected value
//! out of `Arc<Row>` storage — the last per-row allocation left on the
//! read hot path after the prepared-execution pipeline (PR 1) removed
//! per-call planning and row deep-clones. [`ResultSet`] replaces it with
//! a *borrowed* form:
//!
//! * matched rows are held as `Arc<Row>` handles into committed storage
//!   (or the transaction overlay) — taking a handle is a refcount bump,
//! * the projection is the prepared statement's column-index list,
//!   shared by `Arc` with the [`Prepared`](super::prepared::Prepared)
//!   statement — cloning it per execution is refcount-cheap,
//! * values are resolved lazily through [`RowRef`] accessors and never
//!   cloned; aggregates, which inherently *compute* values, carry their
//!   single computed row inline.
//!
//! Because the handles are `Arc`s (not lifetimes), a `ResultSet` is
//! `'static`: it can outlive its transaction and it keeps reading the
//! snapshot it was built from — later writes in the same transaction go
//! through copy-on-write images, and commits swap new `Arc`s into
//! storage, so held handles are never mutated
//! (`rust/tests/prepared_equivalence.rs` pins this as a property).
//!
//! Callers that genuinely need owned rows use the explicit
//! [`ResultSet::to_owned`] escape hatch; write statements keep their
//! `affected`-count shape.

use super::value::{Row, Value};
use std::fmt;
use std::sync::Arc;

/// The result of executing one statement: borrowed rows for SELECT, an
/// affected-row count for DML. See the [module docs](self) for the
/// design.
#[derive(Clone, Default)]
pub struct ResultSet {
    repr: Repr,
    /// Rows inserted/updated/deleted (DML only; 0 for SELECT).
    pub affected: usize,
}

/// Internal row storage of a [`ResultSet`].
#[derive(Clone)]
enum Repr {
    /// Handles into storage/overlay plus the lazy projection
    /// (`None` = `SELECT *`: every storage column in schema order).
    Rows { rows: Vec<Arc<Row>>, cols: Option<Arc<[usize]>> },
    /// The single locally-computed row of an aggregate query (the one
    /// result shape that inherently owns its values).
    Computed(Row),
}

impl Default for Repr {
    fn default() -> Self {
        // `Vec::new` does not allocate: DML results are allocation-free.
        Repr::Rows { rows: Vec::new(), cols: None }
    }
}

impl ResultSet {
    /// Borrowed SELECT result: row handles plus the prepared statement's
    /// projection indices.
    pub(crate) fn rows(rows: Vec<Arc<Row>>, cols: Option<Arc<[usize]>>) -> Self {
        ResultSet { repr: Repr::Rows { rows, cols }, affected: 0 }
    }

    /// Aggregate result: one locally-computed row.
    pub(crate) fn computed(row: Row) -> Self {
        ResultSet { repr: Repr::Computed(row), affected: 0 }
    }

    /// DML result: no rows, `n` affected.
    pub(crate) fn write(n: usize) -> Self {
        ResultSet { repr: Repr::default(), affected: n }
    }

    /// Build a result from owned rows — the wire-decode path
    /// (`net::proto`): a reply decoded off a socket owns its values and
    /// carries the identity projection. The encode side never uses this;
    /// it iterates [`RowRef`]s and clones nothing.
    pub fn from_owned_rows(rows: Vec<Row>, affected: usize) -> Self {
        ResultSet {
            repr: Repr::Rows { rows: rows.into_iter().map(Arc::new).collect(), cols: None },
            affected,
        }
    }

    /// Number of result rows. Costs nothing — emptiness/length checks
    /// never touch values.
    pub fn len(&self) -> usize {
        match &self.repr {
            Repr::Rows { rows, .. } => rows.len(),
            Repr::Computed(_) => 1,
        }
    }

    /// True when the result has no rows (see [`len`](Self::len)).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `i`-th result row, or `None` past the end.
    pub fn get(&self, i: usize) -> Option<RowRef<'_>> {
        match &self.repr {
            Repr::Rows { rows, cols } => {
                rows.get(i).map(|r| RowRef { row: r.as_ref(), cols: cols.as_deref() })
            }
            Repr::Computed(row) => (i == 0).then_some(RowRef { row, cols: None }),
        }
    }

    /// The `i`-th result row; panics past the end (indexing convenience
    /// for tests and transaction bodies).
    pub fn row(&self, i: usize) -> RowRef<'_> {
        self.get(i).unwrap_or_else(|| panic!("row {i} out of bounds (len {})", self.len()))
    }

    /// The first row, if any.
    pub fn first(&self) -> Option<RowRef<'_>> {
        self.get(0)
    }

    /// Convenience: the single scalar of a one-row/one-col result.
    pub fn scalar(&self) -> Option<&Value> {
        self.first().and_then(|r| r.get(0))
    }

    /// Iterate over the result rows (no values are cloned; see
    /// [`RowRef`]).
    pub fn iter(&self) -> RowIter<'_> {
        RowIter { rs: self, i: 0 }
    }

    /// Materialize the projected rows as owned `Vec<Row>` — the explicit
    /// escape hatch for callers that genuinely need owned values. This is
    /// the only way a read result clones `Value`s. (Shadows the blanket
    /// `ToOwned::to_owned` on purpose: materializing is this type's
    /// natural "owned" form; use `.clone()` for a cheap handle copy.)
    #[allow(clippy::should_implement_trait)]
    pub fn to_owned(&self) -> Vec<Row> {
        self.iter().map(|r| r.to_vec()).collect()
    }
}

impl PartialEq for ResultSet {
    /// Structural equality on the *projected* values plus the affected
    /// count — two results compare equal regardless of whether the values
    /// are borrowed from storage or locally computed.
    fn eq(&self, other: &Self) -> bool {
        self.affected == other.affected
            && self.len() == other.len()
            && self.iter().zip(other.iter()).all(|(a, b)| a == b)
    }
}

impl fmt::Debug for ResultSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ResultSet")
            .field("rows", &self.iter().map(|r| r.iter().collect::<Vec<_>>()).collect::<Vec<_>>())
            .field("affected", &self.affected)
            .finish()
    }
}

impl<'a> IntoIterator for &'a ResultSet {
    type Item = RowRef<'a>;
    type IntoIter = RowIter<'a>;
    fn into_iter(self) -> RowIter<'a> {
        self.iter()
    }
}

/// Iterator over the rows of a [`ResultSet`], yielding [`RowRef`]s.
#[derive(Debug, Clone)]
pub struct RowIter<'a> {
    rs: &'a ResultSet,
    i: usize,
}

impl<'a> Iterator for RowIter<'a> {
    type Item = RowRef<'a>;

    fn next(&mut self) -> Option<RowRef<'a>> {
        let r = self.rs.get(self.i);
        if r.is_some() {
            self.i += 1;
        }
        r
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.rs.len().saturating_sub(self.i);
        (n, Some(n))
    }
}

impl ExactSizeIterator for RowIter<'_> {}

/// A borrowed view of one result row: the stored row plus the lazy
/// projection. Indexing (`row[j]`) and [`get`](Self::get) resolve the
/// `j`-th *projected* column to a `&Value` without cloning.
#[derive(Clone, Copy)]
pub struct RowRef<'a> {
    row: &'a Row,
    /// Projection indices; `None` = identity (all storage columns).
    cols: Option<&'a [usize]>,
}

impl<'a> RowRef<'a> {
    /// Number of projected columns.
    pub fn len(&self) -> usize {
        self.cols.map_or(self.row.len(), <[usize]>::len)
    }

    /// True when the row projects no columns.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `j`-th projected value, or `None` past the projection width.
    pub fn get(&self, j: usize) -> Option<&'a Value> {
        match self.cols {
            Some(cols) => cols.get(j).map(|&ci| &self.row[ci]),
            None => self.row.get(j),
        }
    }

    /// Iterate over the projected values (borrowed — nothing is cloned).
    pub fn iter(&self) -> impl ExactSizeIterator<Item = &'a Value> {
        let row = self.row;
        let cols = self.cols;
        (0..self.len()).map(move |j| match cols {
            Some(cols) => &row[cols[j]],
            None => &row[j],
        })
    }

    /// Clone the projected values into an owned row.
    pub fn to_vec(&self) -> Row {
        self.iter().cloned().collect()
    }
}

impl std::ops::Index<usize> for RowRef<'_> {
    type Output = Value;

    fn index(&self, j: usize) -> &Value {
        self.get(j)
            .unwrap_or_else(|| panic!("column {j} out of bounds (width {})", self.len()))
    }
}

impl PartialEq for RowRef<'_> {
    fn eq(&self, other: &Self) -> bool {
        self.len() == other.len() && self.iter().zip(other.iter()).all(|(a, b)| a == b)
    }
}

impl fmt::Debug for RowRef<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_list().entries(self.iter()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arc_row(vals: &[i64]) -> Arc<Row> {
        Arc::new(vals.iter().map(|&v| Value::Int(v)).collect())
    }

    #[test]
    fn projection_resolves_lazily() {
        let rows = vec![arc_row(&[1, 10, 100]), arc_row(&[2, 20, 200])];
        let cols: Arc<[usize]> = vec![2, 0].into();
        let rs = ResultSet::rows(rows, Some(cols));
        assert_eq!(rs.len(), 2);
        assert_eq!(rs.row(0)[0], Value::Int(100));
        assert_eq!(rs.row(0)[1], Value::Int(1));
        assert_eq!(rs.row(1).to_vec(), vec![Value::Int(200), Value::Int(2)]);
        assert_eq!(rs.scalar(), Some(&Value::Int(100)));
        assert_eq!(rs.to_owned(), vec![
            vec![Value::Int(100), Value::Int(1)],
            vec![Value::Int(200), Value::Int(2)],
        ]);
    }

    #[test]
    fn select_star_projects_all_columns() {
        let rs = ResultSet::rows(vec![arc_row(&[7, 8])], None);
        assert_eq!(rs.row(0).len(), 2);
        assert_eq!(rs.row(0)[1], Value::Int(8));
        assert!(rs.row(0).get(2).is_none());
        assert!(rs.get(1).is_none());
    }

    #[test]
    fn computed_and_write_shapes() {
        let agg = ResultSet::computed(vec![Value::Int(42)]);
        assert_eq!(agg.len(), 1);
        assert_eq!(agg.scalar(), Some(&Value::Int(42)));
        let w = ResultSet::write(3);
        assert_eq!(w.affected, 3);
        assert!(w.is_empty());
        assert!(w.scalar().is_none());
    }

    #[test]
    fn equality_is_projection_aware() {
        // A borrowed projection and a computed row with the same values
        // compare equal.
        let a = ResultSet::rows(vec![arc_row(&[5, 6])], Some(vec![1].into()));
        let b = ResultSet::computed(vec![Value::Int(6)]);
        assert_eq!(a, b);
        let c = ResultSet::computed(vec![Value::Int(7)]);
        assert_ne!(a, c);
    }

    #[test]
    fn iteration_is_exact_size() {
        let rs = ResultSet::rows(vec![arc_row(&[1]), arc_row(&[2]), arc_row(&[3])], None);
        let it = rs.iter();
        assert_eq!(it.len(), 3);
        let vals: Vec<i64> = (&rs).into_iter().map(|r| r[0].as_int().unwrap()).collect();
        assert_eq!(vals, vec![1, 2, 3]);
    }
}
