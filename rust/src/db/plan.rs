//! Access-path planning: decide how a WHERE predicate selects rows.
//!
//! This is the *value-level reference* planner: it inspects concrete
//! bind values and is kept for tests and analysis tooling. The engine's
//! execution path plans once per statement at prepare time instead —
//! [`crate::db::prepared::plan_template`] makes the same decision from
//! the predicate shape alone and fills values in per execution.
//!
//! Three paths, best first:
//! * **Point**: the predicate pins every primary-key column with an
//!   equality — O(1) hash lookup, row-level locking.
//! * **IndexEq**: an equality on a secondary-indexed column — index
//!   bucket scan, row-level locking plus a table intent lock. For
//!   serializable phantom protection an index-equality *read* still
//!   takes a table S lock unless the index column is the full PK prefix;
//!   we keep it simple and treat IndexEq reads like scans lock-wise when
//!   the isolation level demands it (see engine).
//! * **Scan**: everything else — full scan, table-level locking.

use super::value::{eval_scalar, Bindings, Key, Value};
use crate::catalog::TableSchema;
use crate::sqlir::{CmpOp, Pred, Scalar};

/// The chosen access path for a statement's WHERE clause.
#[derive(Debug, Clone, PartialEq)]
pub enum AccessPath {
    /// Full primary key pinned to concrete values.
    Point(Key),
    /// Equality on a secondary-indexed column.
    IndexEq {
        /// Indexed column.
        col: usize,
        /// Concrete probe value.
        value: Value,
    },
    /// Full table scan.
    Scan,
}

/// Extract `col = <concrete value>` equalities from the top-level
/// conjunction of `pred` (disjunctions and non-equalities contribute
/// nothing — they fall back to scan filtering).
fn top_level_equalities(
    pred: &Pred,
    schema: &TableSchema,
    binds: &Bindings,
) -> Vec<(usize, Value)> {
    let mut out = Vec::new();
    collect_eq(pred, schema, binds, &mut out);
    out
}

fn collect_eq(pred: &Pred, schema: &TableSchema, binds: &Bindings, out: &mut Vec<(usize, Value)>) {
    match pred {
        Pred::Cmp { col, op: CmpOp::Eq, rhs } => {
            // Only param/literal right-hand sides yield a concrete value.
            if matches!(rhs, Scalar::Param(_) | Scalar::Lit(_)) {
                if let Some(idx) = schema.col_index(col) {
                    if let Ok(v) = eval_scalar(rhs, None, &|c| schema.col_index(c), binds) {
                        let v = v.coerce(schema.columns[idx].ty);
                        out.push((idx, v));
                    }
                }
            }
        }
        Pred::And(ps) => {
            for p in ps {
                collect_eq(p, schema, binds, out);
            }
        }
        _ => {}
    }
}

/// Plan the access path for `pred` over `schema` with `binds`.
pub fn plan(pred: &Pred, schema: &TableSchema, binds: &Bindings) -> AccessPath {
    let eqs = top_level_equalities(pred, schema, binds);
    // Point access: every PK column pinned.
    let pk = schema.pk_indices();
    let mut key_vals = Vec::with_capacity(pk.len());
    for pkc in &pk {
        match eqs.iter().find(|(c, _)| c == pkc) {
            Some((_, v)) => key_vals.push(v.clone()),
            None => {
                key_vals.clear();
                break;
            }
        }
    }
    if !key_vals.is_empty() && key_vals.len() == pk.len() {
        return AccessPath::Point(Key(key_vals));
    }
    // Secondary index equality.
    for idx_col in &schema.indexes {
        if let Some(ci) = schema.col_index(idx_col) {
            if let Some((_, v)) = eqs.iter().find(|(c, _)| *c == ci) {
                return AccessPath::IndexEq { col: ci, value: v.clone() };
            }
        }
    }
    AccessPath::Scan
}

/// Evaluate a predicate against a row.
pub fn eval_pred(
    pred: &Pred,
    row: &super::value::Row,
    schema: &TableSchema,
    binds: &Bindings,
) -> Result<bool, String> {
    match pred {
        Pred::True => Ok(true),
        Pred::Cmp { col, op, rhs } => {
            let idx = col_or_err(schema, col)?;
            let rv = eval_scalar(rhs, Some(row), &|c| schema.col_index(c), binds)?;
            Ok(row[idx].sql_cmp(*op, &rv))
        }
        Pred::And(ps) => {
            for p in ps {
                if !eval_pred(p, row, schema, binds)? {
                    return Ok(false);
                }
            }
            Ok(true)
        }
        Pred::Or(ps) => {
            for p in ps {
                if eval_pred(p, row, schema, binds)? {
                    return Ok(true);
                }
            }
            Ok(false)
        }
    }
}

fn col_or_err(schema: &TableSchema, col: &str) -> Result<usize, String> {
    schema
        .col_index(col)
        .ok_or_else(|| format!("unknown column {col} in table {}", schema.name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::ValueType;
    use crate::sqlir::parse_statement;
    use crate::sqlir::Stmt;

    fn schema() -> TableSchema {
        TableSchema::new(
            "SC",
            &[
                ("ID", ValueType::Int),
                ("I_ID", ValueType::Int),
                ("QTY", ValueType::Int),
                ("OWNER", ValueType::Int),
            ],
            &["ID", "I_ID"],
        )
        .with_index("OWNER")
    }

    fn where_of(sql: &str) -> Pred {
        match parse_statement(sql).unwrap() {
            Stmt::Select(s) => s.where_,
            Stmt::Update(u) => u.where_,
            Stmt::Delete(d) => d.where_,
            _ => panic!(),
        }
    }

    fn binds(pairs: &[(&str, i64)]) -> Bindings {
        pairs.iter().map(|(k, v)| (k.to_string(), Value::Int(*v))).collect()
    }

    #[test]
    fn point_plan_when_full_pk_pinned() {
        let p = where_of("SELECT * FROM SC WHERE ID = ?sid AND I_ID = ?iid");
        let plan = plan(&p, &schema(), &binds(&[("sid", 5), ("iid", 9)]));
        assert_eq!(plan, AccessPath::Point(Key(vec![Value::Int(5), Value::Int(9)])));
    }

    #[test]
    fn partial_pk_falls_to_scan_or_index() {
        let p = where_of("SELECT * FROM SC WHERE ID = ?sid");
        assert_eq!(plan(&p, &schema(), &binds(&[("sid", 5)])), AccessPath::Scan);
        let p = where_of("SELECT * FROM SC WHERE OWNER = ?u");
        assert_eq!(
            plan(&p, &schema(), &binds(&[("u", 3)])),
            AccessPath::IndexEq { col: 3, value: Value::Int(3) }
        );
    }

    #[test]
    fn disjunction_prevents_point_access() {
        let p = where_of("SELECT * FROM SC WHERE (ID = ?a AND I_ID = ?b) OR QTY = 0");
        assert_eq!(plan(&p, &schema(), &binds(&[("a", 1), ("b", 2)])), AccessPath::Scan);
    }

    #[test]
    fn range_predicate_scans() {
        let p = where_of("SELECT * FROM SC WHERE QTY > 3");
        assert_eq!(plan(&p, &schema(), &Bindings::new()), AccessPath::Scan);
    }

    #[test]
    fn eval_pred_filters_rows() {
        let s = schema();
        let row = vec![Value::Int(1), Value::Int(2), Value::Int(7), Value::Int(4)];
        let p = where_of("SELECT * FROM SC WHERE QTY >= 5 AND OWNER = ?u");
        assert!(eval_pred(&p, &row, &s, &binds(&[("u", 4)])).unwrap());
        assert!(!eval_pred(&p, &row, &s, &binds(&[("u", 9)])).unwrap());
        let p = where_of("SELECT * FROM SC WHERE QTY = 0 OR OWNER = 4");
        assert!(eval_pred(&p, &row, &s, &Bindings::new()).unwrap());
    }

    #[test]
    fn eval_pred_unknown_column_errors() {
        let s = schema();
        let row = vec![Value::Int(1), Value::Int(2), Value::Int(7), Value::Int(4)];
        let p = where_of("SELECT * FROM SC WHERE NOPE = 1");
        assert!(eval_pred(&p, &row, &s, &Bindings::new()).is_err());
    }
}
