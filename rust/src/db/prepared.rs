//! Prepared statements: compile a [`Stmt`] against a [`Schema`] once,
//! execute it many times with positional bindings.
//!
//! The interpreted execution path re-derived everything per call: it
//! re-planned the access path, re-hashed string binding names on every
//! scalar evaluation, and linearly re-resolved column names against the
//! schema for every row it touched. For the simulated servers in
//! `cluster::sim` / `conveyor::sim`, which execute millions of statements
//! per experiment, that tax dominated the single-server hot path.
//!
//! Compilation resolves, once per SQL string:
//!
//! * **table + column names → indices** ([`CScalar::Col`], [`CPred`]),
//! * **binding names → integer slots** ([`BindSlots`]; slot order is the
//!   statement's source order of first occurrence, exposed via
//!   [`Prepared::params`]),
//! * the **access-path template** ([`PathTemplate`]): the point /
//!   index-eq / scan decision depends only on the predicate shape and
//!   the schema, never on bind values — only the concrete key value is
//!   filled in per execution,
//! * the **delta shape** of `SET c = c ± expr` updates ([`SetOp::Delta`]),
//!   so the logical-redo analysis is not repeated per matched row.
//!
//! A name-keyed constructor ([`Prepared::bind`]) is kept for tests,
//! examples and transaction bodies; it costs one small `Vec` plus one
//! map lookup per parameter, after which execution is name-free.

use super::value::{numeric_arith, ArithKind, Bindings, Key, Row, Value};
use crate::catalog::{Schema, TableSchema, ValueType};
use crate::sqlir::{CmpOp, Pred, Scalar, SelectItem, Stmt};

/// Positional parameter values for one execution of a [`Prepared`]
/// statement. Slot `i` corresponds to `prepared.params()[i]`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BindSlots(
    /// Values in slot order.
    pub Vec<Value>,
);

impl BindSlots {
    /// Wrap already-ordered slot values.
    pub fn new(values: Vec<Value>) -> Self {
        BindSlots(values)
    }

    fn get(&self, slot: usize) -> Result<&Value, String> {
        self.0.get(slot).ok_or_else(|| format!("missing bind slot {slot}"))
    }
}

/// A scalar expression with column names resolved to indices and
/// parameter names resolved to slots.
#[derive(Debug, Clone, PartialEq)]
pub enum CScalar {
    /// Literal constant, pre-converted to a runtime [`Value`].
    Lit(Value),
    /// Parameter, resolved to its bind slot.
    Slot(usize),
    /// Column of the statement's table, resolved to its index.
    Col(usize),
    /// Sum of two sub-expressions.
    Add(Box<CScalar>, Box<CScalar>),
    /// Difference of two sub-expressions.
    Sub(Box<CScalar>, Box<CScalar>),
    /// Product of two sub-expressions.
    Mul(Box<CScalar>, Box<CScalar>),
}

/// The right-hand side of a comparison as a *borrowed* value, when the
/// expression is a literal or a bind slot — the shapes every workload
/// predicate uses. Lets [`eval_cpred`] compare without cloning a
/// [`Value`] per row, which is what keeps the scan/index read path free
/// of per-row clones.
fn scalar_ref<'a>(s: &'a CScalar, slots: &'a BindSlots) -> Option<&'a Value> {
    match s {
        CScalar::Lit(v) => Some(v),
        CScalar::Slot(i) => slots.0.get(*i),
        _ => None,
    }
}

/// Evaluate a compiled scalar. `row` may be `None` for row-free contexts
/// (INSERT values, delta expressions).
pub fn eval_cscalar(s: &CScalar, row: Option<&Row>, slots: &BindSlots) -> Result<Value, String> {
    match s {
        CScalar::Lit(v) => Ok(v.clone()),
        CScalar::Slot(i) => slots.get(*i).cloned(),
        CScalar::Col(ci) => {
            let row = row.ok_or_else(|| format!("column #{ci} referenced in row-free context"))?;
            Ok(row[*ci].clone())
        }
        CScalar::Add(a, b) | CScalar::Sub(a, b) | CScalar::Mul(a, b) => {
            let va = eval_cscalar(a, row, slots)?;
            let vb = eval_cscalar(b, row, slots)?;
            let kind = match s {
                CScalar::Add(..) => ArithKind::Add,
                CScalar::Sub(..) => ArithKind::Sub,
                _ => ArithKind::Mul,
            };
            numeric_arith(kind, &va, &vb)
        }
    }
}

/// A predicate with resolved columns and slots.
#[derive(Debug, Clone, PartialEq)]
pub enum CPred {
    /// Matches every row (no WHERE clause).
    True,
    /// Single comparison.
    Cmp {
        /// Left-hand column, resolved to its index.
        col: usize,
        /// Comparison operator.
        op: CmpOp,
        /// Right-hand expression.
        rhs: CScalar,
    },
    /// Conjunction.
    And(Vec<CPred>),
    /// Disjunction.
    Or(Vec<CPred>),
}

/// Evaluate a compiled predicate against a row. Literal/slot right-hand
/// sides are compared by reference — no value is cloned per row.
pub fn eval_cpred(p: &CPred, row: &Row, slots: &BindSlots) -> Result<bool, String> {
    match p {
        CPred::True => Ok(true),
        CPred::Cmp { col, op, rhs } => {
            if let Some(rv) = scalar_ref(rhs, slots) {
                return Ok(row[*col].sql_cmp(*op, rv));
            }
            let rv = eval_cscalar(rhs, Some(row), slots)?;
            Ok(row[*col].sql_cmp(*op, &rv))
        }
        CPred::And(ps) => {
            for p in ps {
                if !eval_cpred(p, row, slots)? {
                    return Ok(false);
                }
            }
            Ok(true)
        }
        CPred::Or(ps) => {
            for p in ps {
                if eval_cpred(p, row, slots)? {
                    return Ok(true);
                }
            }
            Ok(false)
        }
    }
}

/// Where a key / index-probe value comes from at execution time.
#[derive(Debug, Clone, PartialEq)]
pub enum ValueSrc {
    /// Literal, already coerced to the column's declared type.
    Lit(Value),
    /// Bind slot; coerced to the column type per execution.
    Slot(usize, ValueType),
}

impl ValueSrc {
    /// Resolve the concrete (owned, type-coerced) value for one
    /// execution.
    pub fn value(&self, slots: &BindSlots) -> Result<Value, String> {
        match self {
            ValueSrc::Lit(v) => Ok(v.clone()),
            ValueSrc::Slot(i, ty) => Ok(slots.get(*i)?.clone().coerce(*ty)),
        }
    }
}

/// The access-path *template*: the plan decision made once at prepare
/// time. Per execution only the concrete values are filled in.
#[derive(Debug, Clone, PartialEq)]
pub enum PathTemplate {
    /// Full primary key pinned; one source per PK column, in PK order.
    Point(Vec<ValueSrc>),
    /// Equality on a secondary-indexed column.
    IndexEq {
        /// Indexed column.
        col: usize,
        /// Probe value source.
        src: ValueSrc,
    },
    /// Full table scan.
    Scan,
}

impl PathTemplate {
    /// Build the concrete primary key for a `Point` template.
    pub fn point_key(srcs: &[ValueSrc], slots: &BindSlots) -> Result<Key, String> {
        let mut vals = Vec::with_capacity(srcs.len());
        for s in srcs {
            vals.push(s.value(slots)?);
        }
        Ok(Key(vals))
    }
}

/// One compiled SET action of an UPDATE.
#[derive(Debug, Clone, PartialEq)]
pub enum SetOp {
    /// General assignment `c = expr` (may read row columns).
    Assign(CScalar),
    /// `c = c + expr` / `c = c - expr` with a row-independent `expr`:
    /// recorded as a logical delta so replicated replay merges with the
    /// replica's own value (see [`crate::db::update::ColOp::Add`]).
    Delta {
        /// The row-free delta expression.
        expr: CScalar,
        /// True for the `c - expr` form.
        negate: bool,
    },
}

/// Compiled SELECT.
#[derive(Debug, Clone)]
pub struct PSelect {
    /// Table index.
    pub ti: usize,
    /// Compiled WHERE predicate.
    pub where_: CPred,
    /// Access-path template.
    pub path: PathTemplate,
    /// Resolved projection; empty means `SELECT *`.
    pub items: Vec<CItem>,
    /// Pure-column projection indices resolved once at prepare time and
    /// `Arc`-shared with every [`ResultSet`](crate::db::ResultSet) this
    /// statement produces (borrowed result materialization — no index
    /// list is built or copied per execution). `None` for `SELECT *` and
    /// for aggregate queries, which compute their single row instead.
    pub proj: Option<std::sync::Arc<[usize]>>,
    /// Primary-key column indices — the read path's deterministic output
    /// order is by PK value, resolved from the row itself so results
    /// never carry cloned keys.
    pub pk: Vec<usize>,
    /// True when any projection item aggregates.
    pub has_agg: bool,
    /// `ORDER BY` column index and descending flag.
    pub order_by: Option<(usize, bool)>,
    /// `LIMIT` row count.
    pub limit: Option<u64>,
}

/// A resolved projection item.
#[derive(Debug, Clone, PartialEq)]
pub enum CItem {
    /// Plain column, by index.
    Col(usize),
    /// `COUNT(*)`.
    Count,
    /// `MAX(col)`.
    Max(usize),
    /// `MIN(col)`.
    Min(usize),
    /// `SUM(col)`.
    Sum(usize),
}

/// Compiled INSERT.
#[derive(Debug, Clone)]
pub struct PInsert {
    /// Table index.
    pub ti: usize,
    /// `(column index, row-free value expression)` pairs.
    pub sets: Vec<(usize, CScalar)>,
    /// Primary-key column indices, resolved once.
    pub pk: Vec<usize>,
}

/// Compiled UPDATE.
#[derive(Debug, Clone)]
pub struct PUpdate {
    /// Table index.
    pub ti: usize,
    /// Compiled WHERE predicate.
    pub where_: CPred,
    /// Access-path template.
    pub path: PathTemplate,
    /// `(column index, compiled SET action)` pairs.
    pub sets: Vec<(usize, SetOp)>,
}

/// Compiled DELETE.
#[derive(Debug, Clone)]
pub struct PDelete {
    /// Table index.
    pub ti: usize,
    /// Compiled WHERE predicate.
    pub where_: CPred,
    /// Access-path template.
    pub path: PathTemplate,
}

/// The statement kinds in compiled form.
#[derive(Debug, Clone)]
pub enum PreparedKind {
    /// Compiled SELECT.
    Select(PSelect),
    /// Compiled INSERT.
    Insert(PInsert),
    /// Compiled UPDATE.
    Update(PUpdate),
    /// Compiled DELETE.
    Delete(PDelete),
}

/// A statement compiled against a schema: execute with
/// [`crate::db::TxnHandle::exec_prepared`] or
/// [`crate::db::Db::exec_auto_prepared`].
#[derive(Debug, Clone)]
pub struct Prepared {
    params: Vec<String>,
    /// The compiled statement body.
    pub kind: PreparedKind,
}

impl Prepared {
    /// Compile `stmt` against `schema`. Errors are SQL-level (unknown
    /// table / column, PK update, row reference in row-free context).
    pub fn compile(stmt: &Stmt, schema: &Schema) -> Result<Prepared, String> {
        let table_name = stmt.table();
        let ti = schema
            .table_id(table_name)
            .ok_or_else(|| format!("unknown table {table_name}"))?;
        let ts = schema.table(ti);

        // Slot order: source order of first occurrence.
        let mut params: Vec<String> = Vec::new();
        for p in stmt.referenced_params() {
            if !params.iter().any(|q| q == p) {
                params.push(p.to_string());
            }
        }

        let kind = match stmt {
            Stmt::Select(s) => {
                let mut items = Vec::with_capacity(s.items.len());
                for it in &s.items {
                    items.push(match it {
                        SelectItem::Col(c) => CItem::Col(col_of(ts, c)?),
                        SelectItem::Count => CItem::Count,
                        SelectItem::Max(c) => CItem::Max(col_of(ts, c)?),
                        SelectItem::Min(c) => CItem::Min(col_of(ts, c)?),
                        SelectItem::Sum(c) => CItem::Sum(col_of(ts, c)?),
                    });
                }
                let order_by = match &s.order_by {
                    Some((c, desc)) => Some((
                        ts.col_index(c)
                            .ok_or_else(|| format!("unknown ORDER BY column {c}"))?,
                        *desc,
                    )),
                    None => None,
                };
                let has_agg = s.items.iter().any(|i| i.is_aggregate());
                // Pure-column projections resolve to an index list once,
                // shared (`Arc`) with every ResultSet this statement
                // produces.
                let proj: Option<std::sync::Arc<[usize]>> = if has_agg || items.is_empty() {
                    None
                } else {
                    Some(
                        items
                            .iter()
                            .map(|i| match i {
                                CItem::Col(ci) => *ci,
                                _ => unreachable!("no aggregates when has_agg is false"),
                            })
                            .collect(),
                    )
                };
                PreparedKind::Select(PSelect {
                    ti,
                    where_: cpred(&s.where_, ts, &params)?,
                    path: plan_template(&s.where_, ts, &params),
                    has_agg,
                    items,
                    proj,
                    pk: ts.pk_indices(),
                    order_by,
                    limit: s.limit,
                })
            }
            Stmt::Insert(s) => {
                let mut sets = Vec::with_capacity(s.columns.len());
                for (col, scalar) in s.columns.iter().zip(&s.values) {
                    let ci = col_of(ts, col)?;
                    let cs = cscalar(scalar, ts, &params)?;
                    if refs_row(&cs) {
                        return Err(format!("column {col} referenced in row-free context"));
                    }
                    sets.push((ci, cs));
                }
                PreparedKind::Insert(PInsert { ti, sets, pk: ts.pk_indices() })
            }
            Stmt::Update(s) => {
                let pk = ts.pk_indices();
                let mut sets = Vec::with_capacity(s.sets.len());
                for (col, scalar) in &s.sets {
                    let ci = col_of(ts, col)?;
                    if pk.contains(&ci) {
                        return Err(format!(
                            "updates to primary-key column {col} are unsupported"
                        ));
                    }
                    sets.push((ci, setop(scalar, ci, ts, &params)?));
                }
                PreparedKind::Update(PUpdate {
                    ti,
                    where_: cpred(&s.where_, ts, &params)?,
                    path: plan_template(&s.where_, ts, &params),
                    sets,
                })
            }
            Stmt::Delete(s) => PreparedKind::Delete(PDelete {
                ti,
                where_: cpred(&s.where_, ts, &params)?,
                path: plan_template(&s.where_, ts, &params),
            }),
        };
        Ok(Prepared { params, kind })
    }

    /// The table this statement touches.
    pub fn table(&self) -> usize {
        match &self.kind {
            PreparedKind::Select(p) => p.ti,
            PreparedKind::Insert(p) => p.ti,
            PreparedKind::Update(p) => p.ti,
            PreparedKind::Delete(p) => p.ti,
        }
    }

    /// Parameter names in slot order.
    pub fn params(&self) -> &[String] {
        &self.params
    }

    /// The slot of a named parameter.
    pub fn slot(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p == name)
    }

    /// The compiled SET actions of an UPDATE — the per-column delta
    /// shapes ([`SetOp::Delta`] vs [`SetOp::Assign`]) the confluence
    /// pass (`analysis::confluence`) inspects to prove conflicting
    /// writes mergeable. `None` for non-UPDATE statements.
    pub fn update_sets(&self) -> Option<&[(usize, SetOp)]> {
        match &self.kind {
            PreparedKind::Update(u) => Some(&u.sets),
            _ => None,
        }
    }

    /// The compiled column expressions of an INSERT (row-free value
    /// sources per column). `None` for non-INSERT statements.
    pub fn insert_sets(&self) -> Option<&[(usize, CScalar)]> {
        match &self.kind {
            PreparedKind::Insert(i) => Some(&i.sets),
            _ => None,
        }
    }

    /// Name-keyed binding constructor (tests / examples / transaction
    /// bodies): every referenced parameter must be present. Extra entries
    /// in `binds` are ignored.
    pub fn bind(&self, binds: &Bindings) -> Result<BindSlots, String> {
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            out.push(
                binds.get(p).cloned().ok_or_else(|| format!("unbound parameter ?{p}"))?,
            );
        }
        Ok(BindSlots(out))
    }

    /// Slice-of-pairs binding constructor (avoids building a map).
    pub fn bind_pairs(&self, pairs: &[(&str, Value)]) -> Result<BindSlots, String> {
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let v = pairs
                .iter()
                .find(|(k, _)| k == p)
                .map(|(_, v)| v.clone())
                .ok_or_else(|| format!("unbound parameter ?{p}"))?;
            out.push(v);
        }
        Ok(BindSlots(out))
    }
}

fn col_of(ts: &TableSchema, name: &str) -> Result<usize, String> {
    ts.col_index(name)
        .ok_or_else(|| format!("unknown column {name} in {}", ts.name))
}

fn slot_of(params: &[String], name: &str) -> Result<usize, String> {
    params
        .iter()
        .position(|p| p == name)
        .ok_or_else(|| format!("internal: parameter ?{name} missing from slot table"))
}

fn cscalar(s: &Scalar, ts: &TableSchema, params: &[String]) -> Result<CScalar, String> {
    Ok(match s {
        Scalar::Lit(l) => CScalar::Lit(Value::from_literal(l)),
        Scalar::Param(p) => CScalar::Slot(slot_of(params, p)?),
        Scalar::Col(c) => CScalar::Col(col_of(ts, c)?),
        Scalar::Add(a, b) => {
            CScalar::Add(Box::new(cscalar(a, ts, params)?), Box::new(cscalar(b, ts, params)?))
        }
        Scalar::Sub(a, b) => {
            CScalar::Sub(Box::new(cscalar(a, ts, params)?), Box::new(cscalar(b, ts, params)?))
        }
        Scalar::Mul(a, b) => {
            CScalar::Mul(Box::new(cscalar(a, ts, params)?), Box::new(cscalar(b, ts, params)?))
        }
    })
}

fn refs_row(s: &CScalar) -> bool {
    match s {
        CScalar::Col(_) => true,
        CScalar::Add(a, b) | CScalar::Sub(a, b) | CScalar::Mul(a, b) => {
            refs_row(a) || refs_row(b)
        }
        _ => false,
    }
}

fn cpred(p: &Pred, ts: &TableSchema, params: &[String]) -> Result<CPred, String> {
    Ok(match p {
        Pred::True => CPred::True,
        Pred::Cmp { col, op, rhs } => CPred::Cmp {
            col: col_of(ts, col)?,
            op: *op,
            rhs: cscalar(rhs, ts, params)?,
        },
        Pred::And(ps) => {
            CPred::And(ps.iter().map(|p| cpred(p, ts, params)).collect::<Result<_, _>>()?)
        }
        Pred::Or(ps) => {
            CPred::Or(ps.iter().map(|p| cpred(p, ts, params)).collect::<Result<_, _>>()?)
        }
    })
}

/// Compile the delta shape of one SET action: `c = c ± expr` with `expr`
/// reading no row columns becomes [`SetOp::Delta`]; everything else is a
/// general [`SetOp::Assign`]. Mirrors the shape analysis the interpreted
/// path ran per execution.
fn setop(
    scalar: &Scalar,
    target_ci: usize,
    ts: &TableSchema,
    params: &[String],
) -> Result<SetOp, String> {
    let (lhs, rhs, negate) = match scalar {
        Scalar::Add(a, b) => (a, b, false),
        Scalar::Sub(a, b) => (a, b, true),
        _ => return Ok(SetOp::Assign(cscalar(scalar, ts, params)?)),
    };
    if let Scalar::Col(c) = &**lhs {
        if ts.col_index(c) == Some(target_ci) {
            let expr = cscalar(rhs, ts, params)?;
            if !refs_row(&expr) {
                return Ok(SetOp::Delta { expr, negate });
            }
        }
    }
    Ok(SetOp::Assign(cscalar(scalar, ts, params)?))
}

/// Collect `col = <slot|literal>` equalities from the top-level
/// conjunction (disjunctions and non-equalities contribute nothing).
fn collect_eq_srcs(p: &Pred, ts: &TableSchema, params: &[String], out: &mut Vec<(usize, ValueSrc)>) {
    match p {
        Pred::Cmp { col, op: CmpOp::Eq, rhs } => {
            if let Some(ci) = ts.col_index(col) {
                let ty = ts.columns[ci].ty;
                match rhs {
                    Scalar::Lit(l) => {
                        out.push((ci, ValueSrc::Lit(Value::from_literal(l).coerce(ty))));
                    }
                    Scalar::Param(name) => {
                        if let Ok(slot) = slot_of(params, name) {
                            out.push((ci, ValueSrc::Slot(slot, ty)));
                        }
                    }
                    _ => {}
                }
            }
        }
        Pred::And(ps) => {
            for p in ps {
                collect_eq_srcs(p, ts, params, out);
            }
        }
        _ => {}
    }
}

/// Plan the access-path template for `pred` over `ts`. The decision
/// depends only on the predicate shape and the schema — bind values are
/// filled per execution.
pub fn plan_template(pred: &Pred, ts: &TableSchema, params: &[String]) -> PathTemplate {
    let mut eqs = Vec::new();
    collect_eq_srcs(pred, ts, params, &mut eqs);

    // Point access: every PK column pinned.
    let pk = ts.pk_indices();
    let mut srcs = Vec::with_capacity(pk.len());
    for pkc in &pk {
        match eqs.iter().find(|(c, _)| c == pkc) {
            Some((_, s)) => srcs.push(s.clone()),
            None => {
                srcs.clear();
                break;
            }
        }
    }
    if !srcs.is_empty() && srcs.len() == pk.len() {
        return PathTemplate::Point(srcs);
    }
    // Secondary index equality.
    for idx_col in &ts.indexes {
        if let Some(ci) = ts.col_index(idx_col) {
            if let Some((_, s)) = eqs.iter().find(|(c, _)| *c == ci) {
                return PathTemplate::IndexEq { col: ci, src: s.clone() };
            }
        }
    }
    PathTemplate::Scan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{TableSchema, ValueType};
    use crate::sqlir::parse_statement;

    fn schema() -> Schema {
        Schema::new(vec![TableSchema::new(
            "SC",
            &[
                ("ID", ValueType::Int),
                ("I_ID", ValueType::Int),
                ("QTY", ValueType::Int),
                ("OWNER", ValueType::Int),
            ],
            &["ID", "I_ID"],
        )
        .with_index("OWNER")])
    }

    fn prep(sql: &str) -> Prepared {
        Prepared::compile(&parse_statement(sql).unwrap(), &schema()).unwrap()
    }

    #[test]
    fn point_template_when_full_pk_pinned() {
        let p = prep("SELECT * FROM SC WHERE ID = ?sid AND I_ID = ?iid");
        assert_eq!(p.params(), &["sid".to_string(), "iid".to_string()]);
        let PreparedKind::Select(s) = &p.kind else { panic!() };
        assert_eq!(
            s.path,
            PathTemplate::Point(vec![
                ValueSrc::Slot(0, ValueType::Int),
                ValueSrc::Slot(1, ValueType::Int)
            ])
        );
        let key = PathTemplate::point_key(
            match &s.path {
                PathTemplate::Point(srcs) => srcs,
                _ => unreachable!(),
            },
            &BindSlots(vec![Value::Int(5), Value::Int(9)]),
        )
        .unwrap();
        assert_eq!(key, Key(vec![Value::Int(5), Value::Int(9)]));
    }

    #[test]
    fn partial_pk_falls_to_scan_or_index() {
        let p = prep("SELECT * FROM SC WHERE ID = ?sid");
        let PreparedKind::Select(s) = &p.kind else { panic!() };
        assert_eq!(s.path, PathTemplate::Scan);
        let p = prep("SELECT * FROM SC WHERE OWNER = ?u");
        let PreparedKind::Select(s) = &p.kind else { panic!() };
        assert_eq!(
            s.path,
            PathTemplate::IndexEq { col: 3, src: ValueSrc::Slot(0, ValueType::Int) }
        );
    }

    #[test]
    fn disjunction_and_ranges_scan() {
        let p = prep("SELECT * FROM SC WHERE (ID = ?a AND I_ID = ?b) OR QTY = 0");
        let PreparedKind::Select(s) = &p.kind else { panic!() };
        assert_eq!(s.path, PathTemplate::Scan);
        let p = prep("SELECT * FROM SC WHERE QTY > 3");
        let PreparedKind::Select(s) = &p.kind else { panic!() };
        assert_eq!(s.path, PathTemplate::Scan);
    }

    #[test]
    fn literal_key_is_precoerced() {
        let p = prep("SELECT * FROM SC WHERE ID = 3.0 AND I_ID = 4");
        let PreparedKind::Select(s) = &p.kind else { panic!() };
        assert_eq!(
            s.path,
            PathTemplate::Point(vec![
                ValueSrc::Lit(Value::Int(3)),
                ValueSrc::Lit(Value::Int(4))
            ])
        );
    }

    #[test]
    fn delta_shape_detected_once() {
        let p = prep("UPDATE SC SET QTY = QTY - ?q WHERE ID = ?sid AND I_ID = ?iid");
        let PreparedKind::Update(u) = &p.kind else { panic!() };
        assert_eq!(u.sets.len(), 1);
        assert_eq!(u.sets[0].0, 2);
        assert_eq!(u.sets[0].1, SetOp::Delta { expr: CScalar::Slot(0), negate: true });
        // General assignment stays Assign.
        let p = prep("UPDATE SC SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid");
        let PreparedKind::Update(u) = &p.kind else { panic!() };
        assert_eq!(u.sets[0].1, SetOp::Assign(CScalar::Slot(0)));
    }

    #[test]
    fn pk_update_rejected_at_compile_time() {
        let err =
            Prepared::compile(&parse_statement("UPDATE SC SET ID = 1").unwrap(), &schema())
                .unwrap_err();
        assert!(err.contains("primary-key"), "{err}");
    }

    #[test]
    fn bind_resolves_names_to_slots() {
        let p = prep("SELECT QTY FROM SC WHERE I_ID = ?iid AND ID = ?sid");
        // Source order of first occurrence: iid before sid.
        assert_eq!(p.slot("iid"), Some(0));
        assert_eq!(p.slot("sid"), Some(1));
        let slots = p
            .bind_pairs(&[("sid", Value::Int(1)), ("iid", Value::Int(2))])
            .unwrap();
        assert_eq!(slots, BindSlots(vec![Value::Int(2), Value::Int(1)]));
        let err = p.bind_pairs(&[("sid", Value::Int(1))]).unwrap_err();
        assert!(err.contains("unbound parameter ?iid"), "{err}");
    }

    #[test]
    fn eval_cpred_matches_rows() {
        let p = prep("SELECT * FROM SC WHERE QTY >= 5 AND OWNER = ?u");
        let PreparedKind::Select(s) = &p.kind else { panic!() };
        let row = vec![Value::Int(1), Value::Int(2), Value::Int(7), Value::Int(4)];
        let yes = BindSlots(vec![Value::Int(4)]);
        let no = BindSlots(vec![Value::Int(9)]);
        assert!(eval_cpred(&s.where_, &row, &yes).unwrap());
        assert!(!eval_cpred(&s.where_, &row, &no).unwrap());
    }

    #[test]
    fn unknown_column_errors_at_compile_time() {
        let err =
            Prepared::compile(&parse_statement("SELECT * FROM SC WHERE NOPE = 1").unwrap(), &schema())
                .unwrap_err();
        assert!(err.contains("unknown column"), "{err}");
    }
}
