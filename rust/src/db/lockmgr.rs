//! Strict two-phase locking with intent locks and wait-die deadlock
//! avoidance.
//!
//! Lock targets are either a whole table (intent and scan locks: IS, IX,
//! S, X) or a single row addressed by primary key (S, X). Scans take a
//! table-level S (readers) or X (writers) lock, which conflicts with the
//! IX/IS taken by point writers/readers — this also gives us phantom
//! protection, so serializable really is serializable.
//!
//! Deadlock handling is **wait-die**: a requester older than every
//! incompatible holder waits; a younger requester aborts immediately
//! (`LockAborted`). Transaction age is its globally unique start
//! timestamp. Wait-die guarantees no deadlock (waits only go from older
//! to younger... strictly: older waits for younger is allowed, younger
//! dies — the waits-for graph is acyclic because edges always point from
//! lower to higher timestamp).

use super::value::Key;
use std::collections::HashMap;
use std::fmt;
use std::sync::{Condvar, Mutex};

/// Transaction identifier; also its wait-die timestamp (smaller = older).
pub type TxnId = u64;

/// Lock modes. Rows only use `S`/`X`; tables use all four.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LockMode {
    /// Intention shared: the txn will take S row locks in this table.
    IS,
    /// Intention exclusive: the txn will take X row locks in this table.
    IX,
    /// Shared (table: read scan; row: point read).
    S,
    /// Exclusive (table: write scan / delete scan; row: point write).
    X,
}

impl LockMode {
    /// Standard multi-granularity compatibility matrix.
    pub fn compatible(self, other: LockMode) -> bool {
        use LockMode::*;
        match (self, other) {
            (IS, IS) | (IS, IX) | (IX, IS) | (IX, IX) => true,
            (IS, S) | (S, IS) => true,
            (S, S) => true,
            _ => false,
        }
    }

    /// Whether `self` subsumes `other` (a holder of `self` needs no new
    /// lock to also hold `other`).
    pub fn covers(self, other: LockMode) -> bool {
        use LockMode::*;
        match (self, other) {
            (X, _) => true,
            (S, S) | (S, IS) => true,
            (IX, IX) | (IX, IS) => true,
            (IS, IS) => true,
            _ => self == other,
        }
    }

    /// The weakest mode that subsumes both (for upgrades: S + IX -> X is
    /// the classic SIX case; we conservatively jump to X).
    fn join(self, other: LockMode) -> LockMode {
        use LockMode::*;
        if self.covers(other) {
            return self;
        }
        if other.covers(self) {
            return other;
        }
        match (self, other) {
            (IS, IX) | (IX, IS) => IX,
            _ => X,
        }
    }
}

/// A lockable resource. `Copy`, so acquiring a lock never clones a key:
/// rows are addressed by `(table, key hash)` with the hash precomputed
/// once per statement via [`Key::lock_hash`]. A hash collision merges
/// two lock targets — safe (coarser locking only adds blocking, so
/// serializability is preserved), and vanishingly rare at 64 bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LockTarget {
    /// Whole-table lock (intent and scan modes).
    Table(usize),
    /// Row lock: `(table id, precomputed key hash)`.
    Row(usize, u64),
}

impl LockTarget {
    /// The row-lock target for `key` in `table`.
    pub fn row(table: usize, key: &Key) -> LockTarget {
        LockTarget::Row(table, key.lock_hash())
    }
}

/// Why a lock acquisition failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LockError {
    /// Wait-die chose this (younger) transaction as the victim.
    Aborted {
        /// The aborted transaction.
        txn: TxnId,
        /// Rendered lock target (diagnostics).
        target: String,
    },
    /// Lock wait exceeded the configured timeout (used as a backstop).
    Timeout {
        /// The timed-out transaction.
        txn: TxnId,
        /// Rendered lock target (diagnostics).
        target: String,
    },
}

impl fmt::Display for LockError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LockError::Aborted { txn, target } => {
                write!(f, "transaction {txn} aborted by wait-die on {target:?}")
            }
            LockError::Timeout { txn, target } => {
                write!(f, "transaction {txn} timed out waiting for {target:?}")
            }
        }
    }
}

impl std::error::Error for LockError {}

/// Outcome of a successful [`LockManager::acquire`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Acquired {
    /// First hold of this txn on the target.
    Fresh,
    /// Re-entrant hit or in-place mode upgrade on an existing hold.
    Held,
}

#[derive(Debug, Default)]
struct LockEntry {
    /// Current holders and their (joined) modes.
    holders: Vec<(TxnId, LockMode)>,
}

#[derive(Debug, Default)]
struct Shard {
    entries: HashMap<LockTarget, LockEntry>,
}

/// The lock table, sharded to reduce mutex contention; each shard has a
/// condvar that waiters park on.
pub struct LockManager {
    shards: Vec<(Mutex<Shard>, Condvar)>,
    timeout: std::time::Duration,
}

impl std::fmt::Debug for LockManager {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LockManager").field("shards", &self.shards.len()).finish()
    }
}

const DEFAULT_SHARDS: usize = 32;

/// Shard count for [`LockManager::default`]: the `ELIA_LOCK_SHARDS`
/// value when set and parseable, else 32. The knob exists for tuning —
/// the `bench-sim` shard sweep measures exactly this axis — without
/// recompiling every embedder of the default lock table.
fn default_shards(env: Option<&str>) -> usize {
    env.and_then(|v| v.parse().ok()).unwrap_or(DEFAULT_SHARDS)
}

impl Default for LockManager {
    fn default() -> Self {
        Self::new(default_shards(std::env::var("ELIA_LOCK_SHARDS").ok().as_deref()))
    }
}

impl LockManager {
    /// A lock table with `nshards` mutex shards (min 1).
    pub fn new(nshards: usize) -> Self {
        LockManager {
            shards: (0..nshards.max(1)).map(|_| (Mutex::new(Shard::default()), Condvar::new())).collect(),
            // Generous backstop; wait-die should prevent true deadlocks.
            timeout: std::time::Duration::from_secs(10),
        }
    }

    /// Set the lock-wait timeout backstop.
    pub fn with_timeout(mut self, t: std::time::Duration) -> Self {
        self.timeout = t;
        self
    }

    /// Shard addressing derives from the target's *stored* hash: row
    /// targets already carry the `Key::lock_hash` computed once per
    /// statement, so the old scheme — running SipHash over the whole
    /// `LockTarget` again — paid a second full hash pass on every
    /// acquire and release. An FNV-style table-id mix plus a 64→64
    /// finalizer (same spirit as `workload::analyzed::route_hash`)
    /// spreads the precomputed bits instead. Shard collisions only
    /// funnel two targets onto one mutex — they never coarsen lock
    /// granularity (pinned in `tests/lock_sharding.rs`).
    fn shard_of(&self, target: &LockTarget) -> usize {
        let h = match *target {
            LockTarget::Table(t) => (t as u64).wrapping_mul(0x100000001B3) ^ 0xcbf29ce484222325,
            LockTarget::Row(t, h) => h ^ (t as u64).wrapping_mul(0x100000001B3),
        };
        // Finalizer mix so the modulo sees every input bit.
        let mut x = h;
        x ^= x >> 33;
        x = x.wrapping_mul(0xFF51AFD7ED558CCD);
        x ^= x >> 33;
        (x as usize) % self.shards.len()
    }

    /// The shard a target is addressed to (diagnostics and the sharding
    /// tests): stable for a given target and shard count, and identical
    /// for Eq-equal keys because it is a pure function of
    /// `(table, Key::lock_hash)`.
    pub fn shard_index(&self, target: &LockTarget) -> usize {
        self.shard_of(target)
    }

    /// Number of shards in this lock table.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Acquire `mode` on `target` for `txn`, blocking per wait-die.
    ///
    /// Re-entrant: if the txn already holds a covering mode this is a
    /// no-op; holding a weaker mode upgrades in place (subject to the
    /// same compatibility/wait-die rules against *other* holders).
    /// Returns [`Acquired::Fresh`] only for the txn's first hold on this
    /// target, so callers can track distinct targets for targeted
    /// release without recording re-entrant hits.
    pub fn acquire(
        &self,
        txn: TxnId,
        target: LockTarget,
        mode: LockMode,
    ) -> Result<Acquired, LockError> {
        let sid = self.shard_of(&target);
        let (mutex, cond) = &self.shards[sid];
        let mut shard = mutex.lock().unwrap();
        let deadline = std::time::Instant::now() + self.timeout;
        loop {
            let entry = shard.entries.entry(target).or_default();
            let mine = entry.holders.iter().position(|(t, _)| *t == txn);
            if let Some(i) = mine {
                if entry.holders[i].1.covers(mode) {
                    return Ok(Acquired::Held); // re-entrant
                }
            }
            let want = match mine {
                Some(i) => entry.holders[i].1.join(mode),
                None => mode,
            };
            // Check compatibility against all *other* holders.
            let blockers: Vec<TxnId> = entry
                .holders
                .iter()
                .filter(|(t, m)| *t != txn && !m.compatible(want))
                .map(|(t, _)| *t)
                .collect();
            if blockers.is_empty() {
                match mine {
                    Some(i) => {
                        entry.holders[i].1 = want;
                        return Ok(Acquired::Held); // in-place upgrade
                    }
                    None => {
                        entry.holders.push((txn, want));
                        return Ok(Acquired::Fresh);
                    }
                }
            }
            // Wait-die: if any blocker is older (smaller id), this txn dies.
            if blockers.iter().any(|b| *b < txn) {
                return Err(LockError::Aborted { txn, target: format!("{target:?}") });
            }
            // This txn is older than every blocker: wait.
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(LockError::Timeout { txn, target: format!("{target:?}") });
            }
            let (s, timeout_res) = cond.wait_timeout(shard, deadline - now).unwrap();
            shard = s;
            if timeout_res.timed_out() {
                return Err(LockError::Timeout { txn, target: format!("{target:?}") });
            }
        }
    }

    /// Release exactly the given targets for `txn` (strict 2PL release at
    /// commit/abort when the caller tracked its acquisitions). Touches
    /// only the shards that actually hold the targets, instead of
    /// sweeping every shard like [`release_all`](Self::release_all).
    /// Duplicate targets are harmless. Returns the number released.
    pub fn release(&self, txn: TxnId, targets: &[LockTarget]) -> usize {
        let mut released = 0;
        for target in targets {
            let sid = self.shard_of(target);
            let (mutex, cond) = &self.shards[sid];
            let mut shard = mutex.lock().unwrap();
            if let Some(entry) = shard.entries.get_mut(target) {
                let before = entry.holders.len();
                entry.holders.retain(|(t, _)| *t != txn);
                if entry.holders.len() != before {
                    released += 1;
                    if entry.holders.is_empty() {
                        shard.entries.remove(target);
                    }
                    cond.notify_all();
                }
            }
        }
        released
    }

    /// Release every lock held by `txn` (strict 2PL release at
    /// commit/abort). Returns the number of locks released.
    pub fn release_all(&self, txn: TxnId) -> usize {
        let mut released = 0;
        for (mutex, cond) in &self.shards {
            let mut shard = mutex.lock().unwrap();
            let mut any = false;
            shard.entries.retain(|_, entry| {
                let before = entry.holders.len();
                entry.holders.retain(|(t, _)| *t != txn);
                if entry.holders.len() != before {
                    released += before - entry.holders.len();
                    any = true;
                }
                !entry.holders.is_empty()
            });
            if any {
                cond.notify_all();
            }
        }
        released
    }

    /// Locks currently held by a transaction (diagnostics and tests).
    pub fn held_by(&self, txn: TxnId) -> Vec<(LockTarget, LockMode)> {
        let mut out = Vec::new();
        for (mutex, _) in &self.shards {
            let shard = mutex.lock().unwrap();
            for (target, entry) in &shard.entries {
                for (t, m) in &entry.holders {
                    if *t == txn {
                        out.push((*target, *m));
                    }
                }
            }
        }
        out
    }

    /// Total number of live lock entries (diagnostics).
    pub fn entry_count(&self) -> usize {
        self.shards.iter().map(|(m, _)| m.lock().unwrap().entries.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::value::Value;
    use std::sync::Arc;

    fn row(k: i64) -> LockTarget {
        LockTarget::row(0, &Key::single(Value::Int(k)))
    }

    #[test]
    fn default_shard_count_is_env_configurable() {
        // Pure helper (no env mutation: other tests construct default
        // lock tables concurrently).
        assert_eq!(default_shards(None), 32);
        assert_eq!(default_shards(Some("8")), 8);
        assert_eq!(default_shards(Some("not-a-number")), 32);
        assert_eq!(LockManager::new(0).shard_count(), 1, "min one shard");
    }

    #[test]
    fn compatibility_matrix() {
        use LockMode::*;
        assert!(IS.compatible(IX));
        assert!(S.compatible(S));
        assert!(!S.compatible(X));
        assert!(!X.compatible(X));
        assert!(!IX.compatible(S));
        assert!(IS.compatible(S));
    }

    #[test]
    fn shared_locks_coexist_exclusive_does_not() {
        let lm = LockManager::default();
        lm.acquire(1, row(7), LockMode::S).unwrap();
        lm.acquire(2, row(7), LockMode::S).unwrap();
        // Txn 3 (younger than both) requesting X must die.
        let err = lm.acquire(3, row(7), LockMode::X).unwrap_err();
        assert!(matches!(err, LockError::Aborted { txn: 3, .. }));
        lm.release_all(1);
        lm.release_all(2);
        lm.acquire(3, row(7), LockMode::X).unwrap();
    }

    #[test]
    fn reentrant_and_upgrade() {
        let lm = LockManager::default();
        lm.acquire(5, row(1), LockMode::S).unwrap();
        lm.acquire(5, row(1), LockMode::S).unwrap(); // re-entrant
        lm.acquire(5, row(1), LockMode::X).unwrap(); // sole holder upgrade
        assert_eq!(lm.held_by(5).len(), 1);
        assert_eq!(lm.held_by(5)[0].1, LockMode::X);
        lm.release_all(5);
        assert_eq!(lm.entry_count(), 0);
    }

    #[test]
    fn wait_die_older_waits_for_younger() {
        // Txn 1 (old) requests a lock held by txn 2 (young): it must WAIT,
        // and obtain the lock once 2 releases.
        let lm = Arc::new(LockManager::default());
        lm.acquire(2, row(9), LockMode::X).unwrap();
        let lm2 = Arc::clone(&lm);
        let waiter = std::thread::spawn(move || lm2.acquire(1, row(9), LockMode::X));
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!waiter.is_finished(), "older txn should be blocked, not aborted");
        lm.release_all(2);
        waiter.join().unwrap().unwrap();
    }

    #[test]
    fn targeted_release_wakes_waiters() {
        let lm = Arc::new(LockManager::default());
        lm.acquire(2, row(9), LockMode::X).unwrap();
        lm.acquire(2, LockTarget::Table(0), LockMode::IX).unwrap();
        let lm2 = Arc::clone(&lm);
        let waiter = std::thread::spawn(move || lm2.acquire(1, row(9), LockMode::X));
        std::thread::sleep(std::time::Duration::from_millis(50));
        // Releasing exactly the held targets (with a duplicate) unblocks.
        let n = lm.release(2, &[row(9), LockTarget::Table(0), row(9)]);
        assert_eq!(n, 2);
        waiter.join().unwrap().unwrap();
        lm.release(1, &[row(9)]);
        assert_eq!(lm.entry_count(), 0);
    }

    #[test]
    fn wait_die_younger_dies() {
        let lm = LockManager::default();
        lm.acquire(1, row(3), LockMode::X).unwrap();
        let err = lm.acquire(2, row(3), LockMode::X).unwrap_err();
        assert!(matches!(err, LockError::Aborted { txn: 2, .. }));
    }

    #[test]
    fn table_scan_blocks_point_writer() {
        let lm = LockManager::default();
        lm.acquire(1, LockTarget::Table(0), LockMode::S).unwrap();
        // Younger writer wants IX on the table -> incompatible with S -> dies.
        let err = lm.acquire(2, LockTarget::Table(0), LockMode::IX).unwrap_err();
        assert!(matches!(err, LockError::Aborted { .. }));
        // But another reader's IS is fine.
        lm.acquire(3, LockTarget::Table(0), LockMode::IS).unwrap();
    }

    #[test]
    fn timeout_backstop_fires() {
        let lm = LockManager::new(4).with_timeout(std::time::Duration::from_millis(50));
        lm.acquire(2, row(4), LockMode::X).unwrap();
        // Txn 1 is older so it waits; holder never releases -> timeout.
        let err = lm.acquire(1, row(4), LockMode::X).unwrap_err();
        assert!(matches!(err, LockError::Timeout { txn: 1, .. }));
        lm.release_all(2);
    }

    #[test]
    fn stress_no_two_exclusive_holders() {
        // Property-style stress: N threads hammer M rows with X locks,
        // tracking a per-row owner flag; the flag must never be observed
        // owned by two threads at once.
        use std::sync::atomic::{AtomicU64, Ordering};
        let lm = Arc::new(LockManager::default());
        let owners: Arc<Vec<AtomicU64>> = Arc::new((0..8).map(|_| AtomicU64::new(0)).collect());
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let lm = Arc::clone(&lm);
            let owners = Arc::clone(&owners);
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::Rng::new(t);
                for i in 0..300 {
                    let txn = t * 1_000_000 + i; // unique, interleaved ages
                    let r = rng.range(0, 8);
                    match lm.acquire(txn, row(r as i64), LockMode::X) {
                        Ok(_) => {
                            let prev = owners[r].swap(txn + 1, Ordering::SeqCst);
                            assert_eq!(prev, 0, "row {r} already exclusively owned");
                            std::thread::yield_now();
                            owners[r].store(0, Ordering::SeqCst);
                            lm.release_all(txn);
                        }
                        Err(_) => {
                            lm.release_all(txn);
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(lm.entry_count(), 0);
    }
}
