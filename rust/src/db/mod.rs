//! An embedded, in-memory, multi-threaded relational engine.
//!
//! This is the project's stand-in for the paper's *unmodified DBMS*
//! (MySQL behind JDBC). Eliá treats the DBMS as a black box that offers:
//!
//! 1. ACID transactions with **serializability via strict two-phase
//!    locking** (the Conveyor Belt commit-order argument in §5 of the
//!    paper depends on pessimistic locking),
//! 2. a **read-committed** mode (what MySQL Cluster offers, used by the
//!    data-partitioning baseline),
//! 3. the ability to **capture the state update** of a transaction — the
//!    ordered sequence of mutations it performed — which Eliá's JDBC
//!    interception provided, and
//! 4. the ability to **apply** such a state update directly (replication
//!    of global operations).
//!
//! The engine executes [`crate::sqlir`] statements: point accesses via
//! primary keys, secondary-index lookups, and full scans; inserts,
//! multi-row updates and deletes; COUNT/MIN/MAX/SUM aggregates; ORDER BY
//! and LIMIT.
//!
//! Concurrency control: logical strict-2PL locks (row S/X plus table
//! IS/IX/S/X intent locks for scan/phantom protection) with **wait-die**
//! deadlock avoidance, layered over short physical `RwLock` critical
//! sections per table. Writes are buffered in the transaction and applied
//! at commit, so read-committed readers never observe uncommitted data.
//!
//! The hot path is **prepared-first**: statements are compiled once
//! ([`prepared::Prepared`]) and executed with positional
//! [`prepared::BindSlots`]; rows are `Arc`-shared so reads never deep-
//! copy, and SELECTs return the borrowed [`result::ResultSet`] — values
//! are resolved lazily and never cloned. See `src/db/README.md` and the
//! top-level `ARCHITECTURE.md` for the architecture.
#![cfg_attr(doc, warn(missing_docs))]

pub mod engine;
pub mod lockmgr;
pub mod plan;
pub mod prepared;
pub mod result;
pub mod txn;
pub mod update;
pub mod value;
pub mod wal;

pub use engine::{Db, TxnHandle};
pub use lockmgr::{LockManager, LockMode};
pub use prepared::{BindSlots, Prepared};
pub use result::{ResultSet, RowRef};
pub use txn::{IsolationLevel, Retryable, TxnError};
pub use update::{StateUpdate, WriteRecord};
pub use value::{value_clone_count, Bindings, Key, Row, Value};
pub use wal::{DurabilityConfig, RecoveryReport, SyncPolicy, Wal};
