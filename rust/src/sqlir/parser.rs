//! Recursive-descent parser for the SQL subset.

use super::ast::*;
use super::lexer::{lex, LexError, Token};

#[derive(Debug, Clone, PartialEq)]
pub enum ParseError {
    Lex(LexError),
    Syntax(String),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::Lex(e) => e.fmt(f),
            ParseError::Syntax(msg) => write!(f, "parse error: {msg}"),
        }
    }
}

impl std::error::Error for ParseError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ParseError::Lex(e) => Some(e),
            _ => None,
        }
    }
}

impl From<LexError> for ParseError {
    fn from(e: LexError) -> Self {
        ParseError::Lex(e)
    }
}

/// Parse a single statement of the SQL subset.
pub fn parse_statement(input: &str) -> Result<Stmt, ParseError> {
    let tokens = lex(input)?;
    let mut p = Parser { toks: &tokens, pos: 0 };
    let stmt = p.statement()?;
    if p.pos != p.toks.len() {
        return Err(ParseError::Syntax(format!(
            "trailing tokens starting at {:?}",
            p.toks[p.pos]
        )));
    }
    Ok(stmt)
}

struct Parser<'a> {
    toks: &'a [Token],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<&Token> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Option<&Token> {
        let t = self.toks.get(self.pos);
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError::Syntax(format!("{} (at token {})", msg.into(), self.pos)))
    }

    /// Consume an identifier matching `kw` case-insensitively.
    fn expect_kw(&mut self, kw: &str) -> Result<(), ParseError> {
        match self.next() {
            Some(Token::Ident(s)) if s.eq_ignore_ascii_case(kw) => Ok(()),
            other => Err(ParseError::Syntax(format!("expected keyword {kw}, got {other:?}"))),
        }
    }

    fn peek_kw(&self, kw: &str) -> bool {
        matches!(self.peek(), Some(Token::Ident(s)) if s.eq_ignore_ascii_case(kw))
    }

    fn accept_kw(&mut self, kw: &str) -> bool {
        if self.peek_kw(kw) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect_tok(&mut self, t: Token) -> Result<(), ParseError> {
        match self.next() {
            Some(got) if *got == t => Ok(()),
            other => Err(ParseError::Syntax(format!("expected {t:?}, got {other:?}"))),
        }
    }

    fn ident(&mut self) -> Result<String, ParseError> {
        match self.next() {
            Some(Token::Ident(s)) => Ok(s.clone()),
            other => Err(ParseError::Syntax(format!("expected identifier, got {other:?}"))),
        }
    }

    fn statement(&mut self) -> Result<Stmt, ParseError> {
        match self.peek() {
            Some(Token::Ident(s)) if s.eq_ignore_ascii_case("SELECT") => self.select(),
            Some(Token::Ident(s)) if s.eq_ignore_ascii_case("INSERT") => self.insert(),
            Some(Token::Ident(s)) if s.eq_ignore_ascii_case("UPDATE") => self.update(),
            Some(Token::Ident(s)) if s.eq_ignore_ascii_case("DELETE") => self.delete(),
            other => self.err(format!("expected statement keyword, got {other:?}")),
        }
    }

    fn select(&mut self) -> Result<Stmt, ParseError> {
        self.expect_kw("SELECT")?;
        let mut items = Vec::new();
        if matches!(self.peek(), Some(Token::Star)) {
            self.next();
        } else {
            loop {
                items.push(self.select_item()?);
                if matches!(self.peek(), Some(Token::Comma)) {
                    self.next();
                } else {
                    break;
                }
            }
        }
        self.expect_kw("FROM")?;
        let table = self.ident()?;
        let where_ = self.opt_where()?;
        let order_by = if self.accept_kw("ORDER") {
            self.expect_kw("BY")?;
            let col = self.ident()?;
            let desc = self.accept_kw("DESC");
            if !desc {
                self.accept_kw("ASC");
            }
            Some((col, desc))
        } else {
            None
        };
        let limit = if self.accept_kw("LIMIT") {
            match self.next() {
                Some(Token::Int(n)) if *n >= 0 => Some(*n as u64),
                other => return Err(ParseError::Syntax(format!("bad LIMIT: {other:?}"))),
            }
        } else {
            None
        };
        Ok(Stmt::Select(Select { table, items, where_, order_by, limit }))
    }

    fn select_item(&mut self) -> Result<SelectItem, ParseError> {
        let name = self.ident()?;
        let upper = name.to_ascii_uppercase();
        if matches!(upper.as_str(), "COUNT" | "MAX" | "MIN" | "SUM")
            && matches!(self.peek(), Some(Token::LParen))
        {
            self.next(); // (
            let item = if upper == "COUNT" {
                self.expect_tok(Token::Star)?;
                SelectItem::Count
            } else {
                let col = self.ident()?;
                match upper.as_str() {
                    "MAX" => SelectItem::Max(col),
                    "MIN" => SelectItem::Min(col),
                    "SUM" => SelectItem::Sum(col),
                    _ => unreachable!(),
                }
            };
            self.expect_tok(Token::RParen)?;
            Ok(item)
        } else {
            Ok(SelectItem::Col(name))
        }
    }

    fn insert(&mut self) -> Result<Stmt, ParseError> {
        self.expect_kw("INSERT")?;
        self.expect_kw("INTO")?;
        let table = self.ident()?;
        self.expect_tok(Token::LParen)?;
        let mut columns = Vec::new();
        loop {
            columns.push(self.ident()?);
            if matches!(self.peek(), Some(Token::Comma)) {
                self.next();
            } else {
                break;
            }
        }
        self.expect_tok(Token::RParen)?;
        self.expect_kw("VALUES")?;
        self.expect_tok(Token::LParen)?;
        let mut values = Vec::new();
        loop {
            values.push(self.scalar()?);
            if matches!(self.peek(), Some(Token::Comma)) {
                self.next();
            } else {
                break;
            }
        }
        self.expect_tok(Token::RParen)?;
        if columns.len() != values.len() {
            return self.err(format!(
                "INSERT arity mismatch: {} columns, {} values",
                columns.len(),
                values.len()
            ));
        }
        Ok(Stmt::Insert(Insert { table, columns, values }))
    }

    fn update(&mut self) -> Result<Stmt, ParseError> {
        self.expect_kw("UPDATE")?;
        let table = self.ident()?;
        self.expect_kw("SET")?;
        let mut sets = Vec::new();
        loop {
            let col = self.ident()?;
            self.expect_tok(Token::Eq)?;
            let v = self.scalar()?;
            sets.push((col, v));
            if matches!(self.peek(), Some(Token::Comma)) {
                self.next();
            } else {
                break;
            }
        }
        let where_ = self.opt_where()?;
        Ok(Stmt::Update(Update { table, sets, where_ }))
    }

    fn delete(&mut self) -> Result<Stmt, ParseError> {
        self.expect_kw("DELETE")?;
        self.expect_kw("FROM")?;
        let table = self.ident()?;
        let where_ = self.opt_where()?;
        Ok(Stmt::Delete(Delete { table, where_ }))
    }

    fn opt_where(&mut self) -> Result<Pred, ParseError> {
        if self.accept_kw("WHERE") {
            self.pred_or()
        } else {
            Ok(Pred::True)
        }
    }

    // pred_or := pred_and (OR pred_and)*
    fn pred_or(&mut self) -> Result<Pred, ParseError> {
        let mut parts = vec![self.pred_and()?];
        while self.accept_kw("OR") {
            parts.push(self.pred_and()?);
        }
        Ok(if parts.len() == 1 { parts.pop().unwrap() } else { Pred::Or(parts) })
    }

    // pred_and := pred_atom (AND pred_atom)*
    fn pred_and(&mut self) -> Result<Pred, ParseError> {
        let mut parts = vec![self.pred_atom()?];
        while self.accept_kw("AND") {
            parts.push(self.pred_atom()?);
        }
        Ok(if parts.len() == 1 { parts.pop().unwrap() } else { Pred::And(parts) })
    }

    // pred_atom := '(' pred_or ')' | column cmpop scalar
    fn pred_atom(&mut self) -> Result<Pred, ParseError> {
        if matches!(self.peek(), Some(Token::LParen)) {
            self.next();
            let p = self.pred_or()?;
            self.expect_tok(Token::RParen)?;
            return Ok(p);
        }
        let col = self.ident()?;
        let op = match self.next() {
            Some(Token::Eq) => CmpOp::Eq,
            Some(Token::Ne) => CmpOp::Ne,
            Some(Token::Lt) => CmpOp::Lt,
            Some(Token::Le) => CmpOp::Le,
            Some(Token::Gt) => CmpOp::Gt,
            Some(Token::Ge) => CmpOp::Ge,
            other => return Err(ParseError::Syntax(format!("expected comparison, got {other:?}"))),
        };
        let rhs = self.scalar()?;
        Ok(Pred::Cmp { col, op, rhs })
    }

    // scalar := term (('+'|'-') term)*
    fn scalar(&mut self) -> Result<Scalar, ParseError> {
        let mut lhs = self.term()?;
        loop {
            match self.peek() {
                Some(Token::Plus) => {
                    self.next();
                    let rhs = self.term()?;
                    lhs = Scalar::Add(Box::new(lhs), Box::new(rhs));
                }
                Some(Token::Minus) => {
                    self.next();
                    let rhs = self.term()?;
                    lhs = Scalar::Sub(Box::new(lhs), Box::new(rhs));
                }
                _ => break,
            }
        }
        Ok(lhs)
    }

    // term := factor ('*' factor)*
    fn term(&mut self) -> Result<Scalar, ParseError> {
        let mut lhs = self.factor()?;
        while matches!(self.peek(), Some(Token::Star)) {
            self.next();
            let rhs = self.factor()?;
            lhs = Scalar::Mul(Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    // factor := literal | param | column | '(' scalar ')'
    fn factor(&mut self) -> Result<Scalar, ParseError> {
        match self.next().cloned() {
            Some(Token::Int(i)) => Ok(Scalar::Lit(Literal::Int(i))),
            Some(Token::Float(x)) => Ok(Scalar::Lit(Literal::Float(x))),
            Some(Token::Str(s)) => Ok(Scalar::Lit(Literal::Str(s))),
            Some(Token::Minus) => match self.next() {
                Some(Token::Int(i)) => Ok(Scalar::Lit(Literal::Int(-i))),
                Some(Token::Float(x)) => Ok(Scalar::Lit(Literal::Float(-x))),
                other => Err(ParseError::Syntax(format!("expected number after '-', got {other:?}"))),
            },
            Some(Token::Param(p)) => Ok(Scalar::Param(p)),
            Some(Token::Ident(s)) => {
                if s.eq_ignore_ascii_case("NULL") {
                    Ok(Scalar::Lit(Literal::Null))
                } else {
                    Ok(Scalar::Col(s))
                }
            }
            Some(Token::LParen) => {
                let s = self.scalar()?;
                self.expect_tok(Token::RParen)?;
                Ok(s)
            }
            other => Err(ParseError::Syntax(format!("expected scalar, got {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_paper_docart_update() {
        let stmt =
            parse_statement("UPDATE SHOPPING_CARTS SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid")
                .unwrap();
        match &stmt {
            Stmt::Update(u) => {
                assert_eq!(u.table, "SHOPPING_CARTS");
                assert_eq!(u.sets, vec![("QTY".into(), Scalar::Param("q".into()))]);
                match &u.where_ {
                    Pred::And(ps) => assert_eq!(ps.len(), 2),
                    other => panic!("bad where: {other:?}"),
                }
            }
            other => panic!("expected update, got {other:?}"),
        }
    }

    #[test]
    fn parses_paper_createcart_insert() {
        let stmt = parse_statement("INSERT INTO SHOPPING_CARTS (ID) VALUES (?sid)").unwrap();
        match stmt {
            Stmt::Insert(i) => {
                assert_eq!(i.columns, vec!["ID"]);
                assert_eq!(i.values, vec![Scalar::Param("sid".into())]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_select_star_and_projection() {
        let s = parse_statement("SELECT * FROM ITEMS WHERE ID = ?iid").unwrap();
        match s {
            Stmt::Select(sel) => {
                assert!(sel.items.is_empty());
                assert_eq!(sel.table, "ITEMS");
            }
            _ => panic!(),
        }
        let s = parse_statement("SELECT TITLE, COST FROM ITEMS WHERE STOCK > 0 ORDER BY COST DESC LIMIT 10")
            .unwrap();
        match s {
            Stmt::Select(sel) => {
                assert_eq!(sel.items.len(), 2);
                assert_eq!(sel.order_by, Some(("COST".into(), true)));
                assert_eq!(sel.limit, Some(10));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_aggregates() {
        let s = parse_statement("SELECT COUNT(*) FROM BIDS WHERE ITEM_ID = ?iid").unwrap();
        match s {
            Stmt::Select(sel) => assert_eq!(sel.items, vec![SelectItem::Count]),
            _ => panic!(),
        }
        let s = parse_statement("SELECT MAX(AMOUNT) FROM BIDS WHERE ITEM_ID = ?iid").unwrap();
        match s {
            Stmt::Select(sel) => assert_eq!(sel.items, vec![SelectItem::Max("AMOUNT".into())]),
            _ => panic!(),
        }
    }

    #[test]
    fn parses_arithmetic_in_set() {
        let s = parse_statement("UPDATE ITEMS SET STOCK = STOCK - ?qty WHERE ID = ?iid").unwrap();
        match s {
            Stmt::Update(u) => match &u.sets[0].1 {
                Scalar::Sub(a, b) => {
                    assert_eq!(**a, Scalar::Col("STOCK".into()));
                    assert_eq!(**b, Scalar::Param("qty".into()));
                }
                other => panic!("{other:?}"),
            },
            _ => panic!(),
        }
    }

    #[test]
    fn parses_or_with_parens() {
        let s = parse_statement(
            "SELECT * FROM USERS WHERE (ID = ?a OR ID = ?b) AND REGION = 'EU'",
        )
        .unwrap();
        match s {
            Stmt::Select(sel) => match sel.where_ {
                Pred::And(ps) => {
                    assert!(matches!(ps[0], Pred::Or(_)));
                    assert!(matches!(ps[1], Pred::Cmp { .. }));
                }
                other => panic!("{other:?}"),
            },
            _ => panic!(),
        }
    }

    #[test]
    fn parses_delete_and_negative_literal() {
        let s = parse_statement("DELETE FROM CARTS WHERE TTL < -1").unwrap();
        match s {
            Stmt::Delete(d) => match d.where_ {
                Pred::Cmp { rhs: Scalar::Lit(Literal::Int(-1)), .. } => {}
                other => panic!("{other:?}"),
            },
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_trailing_garbage_and_arity_mismatch() {
        assert!(parse_statement("SELECT * FROM T WHERE A = 1 extra junk ,").is_err());
        assert!(parse_statement("INSERT INTO T (A, B) VALUES (1)").is_err());
    }

    #[test]
    fn display_parse_roundtrip() {
        let sources = [
            "UPDATE SHOPPING_CARTS SET QTY = ?q WHERE (ID = ?sid AND I_ID = ?iid)",
            "INSERT INTO SHOPPING_CARTS (ID) VALUES (?sid)",
            "SELECT TITLE FROM ITEMS WHERE ID = ?iid",
            "DELETE FROM CARTS WHERE OWNER = ?uid",
        ];
        for src in sources {
            let stmt = parse_statement(src).unwrap();
            let printed = stmt.to_string();
            let reparsed = parse_statement(&printed).unwrap();
            assert_eq!(stmt, reparsed, "roundtrip failed for {src}");
        }
    }
}
