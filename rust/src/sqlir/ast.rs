//! Abstract syntax for the SQL subset.

use std::fmt;

/// A literal constant.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    Int(i64),
    Float(f64),
    Str(String),
    Null,
}

impl fmt::Display for Literal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Literal::Int(i) => write!(f, "{i}"),
            Literal::Float(x) => write!(f, "{x}"),
            Literal::Str(s) => write!(f, "'{}'", s.replace('\'', "''")),
            Literal::Null => write!(f, "NULL"),
        }
    }
}

/// A value-producing expression: literals, `?parameters`, column
/// references and +,-,* arithmetic (enough for `SET stock = stock - ?q`).
#[derive(Debug, Clone, PartialEq)]
pub enum Scalar {
    Lit(Literal),
    /// Named placeholder `?name`. At execution time bound from the
    /// operation's arguments (or a derived intermediate value); at
    /// analysis time, names matching a transaction input parameter are
    /// candidate partitioning parameters.
    Param(String),
    /// Reference to a column of the statement's (single) table.
    Col(String),
    Add(Box<Scalar>, Box<Scalar>),
    Sub(Box<Scalar>, Box<Scalar>),
    Mul(Box<Scalar>, Box<Scalar>),
}

impl Scalar {
    /// Column names this scalar reads.
    pub fn referenced_cols<'a>(&'a self, out: &mut Vec<&'a str>) {
        match self {
            Scalar::Col(c) => out.push(c),
            Scalar::Add(a, b) | Scalar::Sub(a, b) | Scalar::Mul(a, b) => {
                a.referenced_cols(out);
                b.referenced_cols(out);
            }
            _ => {}
        }
    }

    /// Parameter names this scalar references.
    pub fn referenced_params<'a>(&'a self, out: &mut Vec<&'a str>) {
        match self {
            Scalar::Param(p) => out.push(p),
            Scalar::Add(a, b) | Scalar::Sub(a, b) | Scalar::Mul(a, b) => {
                a.referenced_params(out);
                b.referenced_params(out);
            }
            _ => {}
        }
    }
}

impl fmt::Display for Scalar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Scalar::Lit(l) => write!(f, "{l}"),
            Scalar::Param(p) => write!(f, "?{p}"),
            Scalar::Col(c) => write!(f, "{c}"),
            Scalar::Add(a, b) => write!(f, "({a} + {b})"),
            Scalar::Sub(a, b) => write!(f, "({a} - {b})"),
            Scalar::Mul(a, b) => write!(f, "({a} * {b})"),
        }
    }
}

/// Comparison operators usable in WHERE atoms.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpOp {
    pub fn flip(self) -> CmpOp {
        match self {
            CmpOp::Eq => CmpOp::Eq,
            CmpOp::Ne => CmpOp::Ne,
            CmpOp::Lt => CmpOp::Gt,
            CmpOp::Le => CmpOp::Ge,
            CmpOp::Gt => CmpOp::Lt,
            CmpOp::Ge => CmpOp::Le,
        }
    }
}

impl fmt::Display for CmpOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            CmpOp::Eq => "=",
            CmpOp::Ne => "<>",
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
        };
        f.write_str(s)
    }
}

/// A WHERE predicate: and/or tree over atomic comparisons
/// `column op scalar`.
#[derive(Debug, Clone, PartialEq)]
pub enum Pred {
    /// Always true (absent WHERE clause).
    True,
    Cmp { col: String, op: CmpOp, rhs: Scalar },
    And(Vec<Pred>),
    Or(Vec<Pred>),
}

impl Pred {
    /// Conjunction helper that flattens nested Ands.
    pub fn and(preds: Vec<Pred>) -> Pred {
        let mut flat = Vec::new();
        for p in preds {
            match p {
                Pred::True => {}
                Pred::And(inner) => flat.extend(inner),
                other => flat.push(other),
            }
        }
        match flat.len() {
            0 => Pred::True,
            1 => flat.pop().unwrap(),
            _ => Pred::And(flat),
        }
    }

    /// All column names mentioned anywhere in the predicate.
    pub fn referenced_cols<'a>(&'a self, out: &mut Vec<&'a str>) {
        match self {
            Pred::True => {}
            Pred::Cmp { col, rhs, .. } => {
                out.push(col);
                rhs.referenced_cols(out);
            }
            Pred::And(ps) | Pred::Or(ps) => {
                for p in ps {
                    p.referenced_cols(out);
                }
            }
        }
    }

    /// All parameter names mentioned anywhere in the predicate.
    pub fn referenced_params<'a>(&'a self, out: &mut Vec<&'a str>) {
        match self {
            Pred::True => {}
            Pred::Cmp { rhs, .. } => rhs.referenced_params(out),
            Pred::And(ps) | Pred::Or(ps) => {
                for p in ps {
                    p.referenced_params(out);
                }
            }
        }
    }
}

impl fmt::Display for Pred {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Pred::True => write!(f, "TRUE"),
            Pred::Cmp { col, op, rhs } => write!(f, "{col} {op} {rhs}"),
            Pred::And(ps) => {
                let parts: Vec<String> = ps.iter().map(|p| p.to_string()).collect();
                write!(f, "({})", parts.join(" AND "))
            }
            Pred::Or(ps) => {
                let parts: Vec<String> = ps.iter().map(|p| p.to_string()).collect();
                write!(f, "({})", parts.join(" OR "))
            }
        }
    }
}

/// An item in a SELECT projection list.
#[derive(Debug, Clone, PartialEq)]
pub enum SelectItem {
    /// Plain column reference.
    Col(String),
    /// `COUNT(*)`
    Count,
    Max(String),
    Min(String),
    Sum(String),
}

impl SelectItem {
    pub fn referenced_col(&self) -> Option<&str> {
        match self {
            SelectItem::Col(c) | SelectItem::Max(c) | SelectItem::Min(c) | SelectItem::Sum(c) => {
                Some(c)
            }
            SelectItem::Count => None,
        }
    }

    pub fn is_aggregate(&self) -> bool {
        !matches!(self, SelectItem::Col(_))
    }
}

impl fmt::Display for SelectItem {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SelectItem::Col(c) => write!(f, "{c}"),
            SelectItem::Count => write!(f, "COUNT(*)"),
            SelectItem::Max(c) => write!(f, "MAX({c})"),
            SelectItem::Min(c) => write!(f, "MIN({c})"),
            SelectItem::Sum(c) => write!(f, "SUM({c})"),
        }
    }
}

/// `SELECT items FROM table [WHERE pred] [ORDER BY col [DESC]] [LIMIT n]`
///
/// An empty `items` list means `SELECT *`.
#[derive(Debug, Clone, PartialEq)]
pub struct Select {
    pub table: String,
    pub items: Vec<SelectItem>,
    pub where_: Pred,
    pub order_by: Option<(String, bool)>, // (column, descending)
    pub limit: Option<u64>,
}

/// `INSERT INTO table (cols) VALUES (scalars)`
#[derive(Debug, Clone, PartialEq)]
pub struct Insert {
    pub table: String,
    pub columns: Vec<String>,
    pub values: Vec<Scalar>,
}

/// `UPDATE table SET col = scalar, ... [WHERE pred]`
#[derive(Debug, Clone, PartialEq)]
pub struct Update {
    pub table: String,
    pub sets: Vec<(String, Scalar)>,
    pub where_: Pred,
}

/// `DELETE FROM table [WHERE pred]`
#[derive(Debug, Clone, PartialEq)]
pub struct Delete {
    pub table: String,
    pub where_: Pred,
}

/// A statement in the SQL subset.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    Select(Select),
    Insert(Insert),
    Update(Update),
    Delete(Delete),
}

impl Stmt {
    pub fn table(&self) -> &str {
        match self {
            Stmt::Select(s) => &s.table,
            Stmt::Insert(s) => &s.table,
            Stmt::Update(s) => &s.table,
            Stmt::Delete(s) => &s.table,
        }
    }

    pub fn is_read_only(&self) -> bool {
        matches!(self, Stmt::Select(_))
    }

    /// Every `?param` name the statement references, in source order.
    pub fn referenced_params(&self) -> Vec<&str> {
        let mut out = Vec::new();
        match self {
            Stmt::Select(s) => s.where_.referenced_params(&mut out),
            Stmt::Insert(s) => {
                for v in &s.values {
                    v.referenced_params(&mut out);
                }
            }
            Stmt::Update(s) => {
                for (_, v) in &s.sets {
                    v.referenced_params(&mut out);
                }
                s.where_.referenced_params(&mut out);
            }
            Stmt::Delete(s) => s.where_.referenced_params(&mut out),
        }
        out
    }
}

impl fmt::Display for Stmt {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Stmt::Select(s) => {
                let items = if s.items.is_empty() {
                    "*".to_string()
                } else {
                    s.items.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(", ")
                };
                write!(f, "SELECT {items} FROM {}", s.table)?;
                if s.where_ != Pred::True {
                    write!(f, " WHERE {}", s.where_)?;
                }
                if let Some((col, desc)) = &s.order_by {
                    write!(f, " ORDER BY {col}{}", if *desc { " DESC" } else { "" })?;
                }
                if let Some(n) = s.limit {
                    write!(f, " LIMIT {n}")?;
                }
                Ok(())
            }
            Stmt::Insert(s) => write!(
                f,
                "INSERT INTO {} ({}) VALUES ({})",
                s.table,
                s.columns.join(", "),
                s.values.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ")
            ),
            Stmt::Update(s) => {
                write!(
                    f,
                    "UPDATE {} SET {}",
                    s.table,
                    s.sets
                        .iter()
                        .map(|(c, v)| format!("{c} = {v}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                )?;
                if s.where_ != Pred::True {
                    write!(f, " WHERE {}", s.where_)?;
                }
                Ok(())
            }
            Stmt::Delete(s) => {
                write!(f, "DELETE FROM {}", s.table)?;
                if s.where_ != Pred::True {
                    write!(f, " WHERE {}", s.where_)?;
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pred_and_flattens() {
        let p = Pred::and(vec![
            Pred::True,
            Pred::And(vec![
                Pred::Cmp { col: "a".into(), op: CmpOp::Eq, rhs: Scalar::Lit(Literal::Int(1)) },
            ]),
            Pred::Cmp { col: "b".into(), op: CmpOp::Eq, rhs: Scalar::Param("p".into()) },
        ]);
        match p {
            Pred::And(ps) => assert_eq!(ps.len(), 2),
            other => panic!("expected And, got {other:?}"),
        }
    }

    #[test]
    fn pred_and_single_collapses() {
        let p = Pred::and(vec![Pred::True, Pred::True]);
        assert_eq!(p, Pred::True);
    }

    #[test]
    fn scalar_referenced_cols_and_params() {
        let s = Scalar::Sub(
            Box::new(Scalar::Col("stock".into())),
            Box::new(Scalar::Param("qty".into())),
        );
        let mut cols = Vec::new();
        s.referenced_cols(&mut cols);
        assert_eq!(cols, vec!["stock"]);
        let mut params = Vec::new();
        s.referenced_params(&mut params);
        assert_eq!(params, vec!["qty"]);
    }

    #[test]
    fn cmp_op_flip() {
        assert_eq!(CmpOp::Lt.flip(), CmpOp::Gt);
        assert_eq!(CmpOp::Eq.flip(), CmpOp::Eq);
        assert_eq!(CmpOp::Ge.flip(), CmpOp::Le);
    }

    #[test]
    fn display_roundtrips_visually() {
        let stmt = Stmt::Update(Update {
            table: "SHOPPING_CARTS".into(),
            sets: vec![("QTY".into(), Scalar::Param("q".into()))],
            where_: Pred::And(vec![
                Pred::Cmp {
                    col: "ID".into(),
                    op: CmpOp::Eq,
                    rhs: Scalar::Param("sid".into()),
                },
                Pred::Cmp {
                    col: "I_ID".into(),
                    op: CmpOp::Eq,
                    rhs: Scalar::Param("iid".into()),
                },
            ]),
        });
        assert_eq!(
            stmt.to_string(),
            "UPDATE SHOPPING_CARTS SET QTY = ?q WHERE (ID = ?sid AND I_ID = ?iid)"
        );
    }
}
