//! `sqlir` — the SQL subset Eliá's static analysis and embedded engine
//! share.
//!
//! The paper's analysis consumes the SQL statements embedded in the
//! application's transaction code (extracted with JavaParser). Here the
//! application's transactions are *templates*: named SQL statements with
//! `?param` placeholders plus a procedural body that executes them. Both
//! the Operation Partitioning analysis ([`crate::analysis`]) and the
//! embedded database engine ([`crate::db`]) operate on this one parsed
//! representation, so the statements the analysis reasons about are — by
//! construction — the statements the application executes.
//!
//! Supported grammar (per the paper §3.1 "Applicability"): single-table
//! SELECT / INSERT / UPDATE / DELETE; WHERE clauses as and/or trees of
//! atomic comparisons; parameters only in atomic conditions; ORDER BY /
//! LIMIT on SELECT; COUNT/MIN/MAX/SUM aggregates. No nested queries, no
//! joins (application-side joins are sequences of statements, as in the
//! benchmark servlets), no triggers.

pub mod ast;
pub mod lexer;
pub mod parser;

pub use ast::*;
pub use parser::{parse_statement, ParseError};
