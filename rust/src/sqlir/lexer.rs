//! Hand-written lexer for the SQL subset.

use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Token {
    /// Keyword or identifier (keywords are matched case-insensitively by
    /// the parser; identifiers keep their original case).
    Ident(String),
    /// `?name` placeholder.
    Param(String),
    Int(i64),
    Float(f64),
    Str(String),
    Comma,
    LParen,
    RParen,
    Star,
    Plus,
    Minus,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl fmt::Display for Token {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Token::Ident(s) => write!(f, "{s}"),
            Token::Param(p) => write!(f, "?{p}"),
            Token::Int(i) => write!(f, "{i}"),
            Token::Float(x) => write!(f, "{x}"),
            Token::Str(s) => write!(f, "'{s}'"),
            Token::Comma => write!(f, ","),
            Token::LParen => write!(f, "("),
            Token::RParen => write!(f, ")"),
            Token::Star => write!(f, "*"),
            Token::Plus => write!(f, "+"),
            Token::Minus => write!(f, "-"),
            Token::Eq => write!(f, "="),
            Token::Ne => write!(f, "<>"),
            Token::Lt => write!(f, "<"),
            Token::Le => write!(f, "<="),
            Token::Gt => write!(f, ">"),
            Token::Ge => write!(f, ">="),
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LexError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for LexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lex error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for LexError {}

/// Tokenize `input`. Whitespace separates tokens; strings use single
/// quotes with `''` escaping.
pub fn lex(input: &str) -> Result<Vec<Token>, LexError> {
    let bytes = input.as_bytes();
    let mut i = 0;
    let mut out = Vec::new();
    while i < bytes.len() {
        let c = bytes[i] as char;
        match c {
            ' ' | '\t' | '\n' | '\r' => i += 1,
            ',' => {
                out.push(Token::Comma);
                i += 1;
            }
            '(' => {
                out.push(Token::LParen);
                i += 1;
            }
            ')' => {
                out.push(Token::RParen);
                i += 1;
            }
            '*' => {
                out.push(Token::Star);
                i += 1;
            }
            '+' => {
                out.push(Token::Plus);
                i += 1;
            }
            '-' => {
                out.push(Token::Minus);
                i += 1;
            }
            '=' => {
                out.push(Token::Eq);
                i += 1;
            }
            '!' => {
                if i + 1 < bytes.len() && bytes[i + 1] == b'=' {
                    out.push(Token::Ne);
                    i += 2;
                } else {
                    return Err(LexError { pos: i, msg: "lone '!'".into() });
                }
            }
            '<' => {
                if i + 1 < bytes.len() && bytes[i + 1] == b'=' {
                    out.push(Token::Le);
                    i += 2;
                } else if i + 1 < bytes.len() && bytes[i + 1] == b'>' {
                    out.push(Token::Ne);
                    i += 2;
                } else {
                    out.push(Token::Lt);
                    i += 1;
                }
            }
            '>' => {
                if i + 1 < bytes.len() && bytes[i + 1] == b'=' {
                    out.push(Token::Ge);
                    i += 2;
                } else {
                    out.push(Token::Gt);
                    i += 1;
                }
            }
            '?' => {
                let start = i + 1;
                let mut j = start;
                while j < bytes.len() && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_') {
                    j += 1;
                }
                if j == start {
                    return Err(LexError { pos: i, msg: "'?' with no parameter name".into() });
                }
                out.push(Token::Param(input[start..j].to_string()));
                i = j;
            }
            '\'' => {
                let mut s = String::new();
                let mut j = i + 1;
                loop {
                    if j >= bytes.len() {
                        return Err(LexError { pos: i, msg: "unterminated string".into() });
                    }
                    if bytes[j] == b'\'' {
                        if j + 1 < bytes.len() && bytes[j + 1] == b'\'' {
                            s.push('\'');
                            j += 2;
                        } else {
                            j += 1;
                            break;
                        }
                    } else {
                        s.push(bytes[j] as char);
                        j += 1;
                    }
                }
                out.push(Token::Str(s));
                i = j;
            }
            '0'..='9' => {
                let start = i;
                let mut j = i;
                let mut is_float = false;
                while j < bytes.len()
                    && (bytes[j].is_ascii_digit() || (bytes[j] == b'.' && !is_float))
                {
                    if bytes[j] == b'.' {
                        is_float = true;
                    }
                    j += 1;
                }
                let text = &input[start..j];
                if is_float {
                    let v = text
                        .parse()
                        .map_err(|_| LexError { pos: start, msg: format!("bad float {text:?}") })?;
                    out.push(Token::Float(v));
                } else {
                    let v = text
                        .parse()
                        .map_err(|_| LexError { pos: start, msg: format!("bad int {text:?}") })?;
                    out.push(Token::Int(v));
                }
                i = j;
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                let mut j = i;
                while j < bytes.len()
                    && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_' || bytes[j] == b'.')
                {
                    j += 1;
                }
                out.push(Token::Ident(input[start..j].to_string()));
                i = j;
            }
            other => {
                return Err(LexError { pos: i, msg: format!("unexpected character {other:?}") })
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_update_statement() {
        let toks =
            lex("UPDATE SC SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid").unwrap();
        assert_eq!(toks[0], Token::Ident("UPDATE".into()));
        assert!(toks.contains(&Token::Param("sid".into())));
        assert!(toks.contains(&Token::Eq));
    }

    #[test]
    fn lexes_numbers_and_strings() {
        let toks = lex("VALUES (3, 2.5, 'it''s')").unwrap();
        assert_eq!(
            toks,
            vec![
                Token::Ident("VALUES".into()),
                Token::LParen,
                Token::Int(3),
                Token::Comma,
                Token::Float(2.5),
                Token::Comma,
                Token::Str("it's".into()),
                Token::RParen,
            ]
        );
    }

    #[test]
    fn lexes_comparison_operators() {
        let toks = lex("a <= b >= c <> d != e < f > g").unwrap();
        let ops: Vec<&Token> = toks
            .iter()
            .filter(|t| !matches!(t, Token::Ident(_)))
            .collect();
        assert_eq!(
            ops,
            vec![&Token::Le, &Token::Ge, &Token::Ne, &Token::Ne, &Token::Lt, &Token::Gt]
        );
    }

    #[test]
    fn rejects_unterminated_string() {
        assert!(lex("'abc").is_err());
    }

    #[test]
    fn rejects_bare_question_mark() {
        assert!(lex("WHERE a = ?").is_err());
    }
}
