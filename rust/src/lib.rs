//! Eliá — Operation Partitioning + the Conveyor Belt protocol.
//!
//! A reproduction of "Scaling Out ACID Applications with Operation
//! Partitioning" (Saissi, Serafini, Suri; 2018): static analysis that
//! partitions an OLTP application's *operations* (indirectly partitioning
//! its data), an operation classification into commutative / local /
//! global, and the lock-free Conveyor Belt token protocol that scales the
//! application across servers while guaranteeing serializability.
#![allow(clippy::too_many_arguments)]

pub mod analysis;
pub mod conveyor;
pub mod baselines;
pub mod catalog;
pub mod cluster;
pub mod db;
pub mod harness;
pub mod net;
pub mod runtime;
pub mod simnet;
pub mod sqlir;
pub mod util;
pub mod workload;
