//! Invariant-confluence classification — the pass that widens the
//! coordination-free class beyond conflict-set disjointness.
//!
//! The conflict-only classifier (`classify`) demotes a transaction to
//! `Global` as soon as one write-write clause cannot be covered by
//! routing. That is sound but pessimistic: many of those clauses are
//! *mergeable* — both sides are delta-shaped writes whose worst-case
//! composition provably preserves every invariant declared on the schema
//! ([`crate::catalog::Invariant`]). Such operations need no token: they
//! execute immediately at their home server, the engine's bounded-apply
//! check enforces the invariant locally (abort instead of coordinate),
//! and their state updates replicate as merged deltas when the token
//! next passes ([`crate::db::update::ColOp::Add`] commutes).
//!
//! [`reclassify`] inspects every `Global` / `LocalGlobal` transaction and
//! promotes it to [`OpClass::Confluent`] when **every clause of every
//! pairwise ww condition** is either
//!
//! 1. **delta-mergeable** — both statements update the shared attributes
//!    with row-free deltas (`SET c = c ± e`, [`SetOp::Delta`]); on a
//!    column declared `NonNegative` the candidate's delta must also be
//!    provably non-decreasing (non-negative literal, a parameter the
//!    workload declares non-negative via
//!    [`TxnTemplate::with_nonneg_param`], or sums/products of such).
//!    The escrow argument: only non-decreasing deltas float belt-free,
//!    decrementers stay token-serialized and validate their post-image
//!    locally, so no interleaving drives the column below a validated
//!    floor;
//! 2. **fresh-key mergeable** — one side is an INSERT and the clause
//!    pins, on both sides, an attribute declared `Unique`: uniqueness is
//!    enforced structurally (duplicate keys abort), so no two committed
//!    operations ever collide on the row; or
//! 3. **covered by routing** — for *every* routing parameter of the
//!    candidate there is a routing parameter of the peer covering the
//!    clause, so the conflicting operations meet at one server and its
//!    local locks serialize them (general assignments survive this way).
//!
//! Write-read conflicts never block confluence. This is a deliberate
//! weakening with the same semantics as `weak_reads`: a reader of a
//! confluent writer observes its server's **consistent prefix** of that
//! writer's totally-ordered (per-origin) delta stream, rather than a
//! globally up-to-date value. Applications that need read-your-writes
//! across servers should not declare the enabling invariants.

use super::classify::{Classification, OpClass};
use super::conflict::{attrs_intersect, pair_condition, SClause, SidedRhs};
use super::rwsets::{AttrId, RwSets};
use crate::catalog::Schema;
use crate::db::prepared::{CScalar, PreparedKind, SetOp};
use crate::db::{Prepared, Value};
use crate::sqlir::CmpOp;
use crate::workload::spec::TxnTemplate;
use std::collections::HashMap;

/// Delta shape of one written column of an UPDATE.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DKind {
    /// `c = c + e` with `e` provably non-negative: safe against a
    /// `NonNegative` invariant in any interleaving.
    SafeDelta,
    /// `c = c ± e`: commutes, but may decrease the column.
    Delta,
    /// General assignment: never ww-mergeable.
    Assign,
}

/// Write shape of one statement, derived from its compiled form.
#[derive(Debug, Clone)]
enum WriteShape {
    /// UPDATE: per written column index, its delta kind.
    Update { cols: HashMap<usize, DKind> },
    /// INSERT: row creation; mergeability argued via declared uniqueness.
    Insert,
    /// DELETE: row removal merges with nothing.
    Delete,
    /// Compilation failed — treat as unmergeable.
    Unknown,
}

/// How a (write, write) entry pair may be discharged, decided once per
/// pair; clause-level checks then pick the applicable rule.
enum PairRule {
    /// Delta-vs-delta on every shared attribute: every clause merges.
    Mergeable,
    /// An INSERT is involved: a clause merges iff it pins a `Unique`
    /// attribute on both sides.
    InsertFresh,
    /// Deletes, assignments, unknown shapes: only routing coverage helps.
    NeedsCoverage,
}

/// Is `expr` provably non-negative? Literals must be `>= 0`; a bind slot
/// must name a parameter the template declares non-negative; sums and
/// products of non-negatives are non-negative. Differences, column
/// references and everything else are conservatively rejected.
fn expr_nonneg(expr: &CScalar, slot_names: &[String], nonneg_params: &[String]) -> bool {
    match expr {
        CScalar::Lit(Value::Int(i)) => *i >= 0,
        CScalar::Lit(Value::Float(x)) => *x >= 0.0,
        CScalar::Lit(_) => false,
        CScalar::Slot(i) => slot_names
            .get(*i)
            .map_or(false, |n| nonneg_params.iter().any(|p| p == n)),
        CScalar::Add(a, b) | CScalar::Mul(a, b) => {
            expr_nonneg(a, slot_names, nonneg_params)
                && expr_nonneg(b, slot_names, nonneg_params)
        }
        _ => false,
    }
}

/// Compile each statement of `tpl` and record its write shape, keyed by
/// statement name (which is what [`AccessEntry::stmt`] carries).
///
/// [`AccessEntry::stmt`]: super::rwsets::AccessEntry
fn profile(tpl: &TxnTemplate, schema: &Schema) -> HashMap<String, WriteShape> {
    let mut out = HashMap::new();
    for (name, stmt) in &tpl.stmts {
        let shape = match Prepared::compile(stmt, schema) {
            Ok(p) => match &p.kind {
                PreparedKind::Select(_) => continue,
                PreparedKind::Insert(_) => WriteShape::Insert,
                PreparedKind::Delete(_) => WriteShape::Delete,
                PreparedKind::Update(u) => {
                    let cols = u
                        .sets
                        .iter()
                        .map(|(ci, op)| {
                            let kind = match op {
                                SetOp::Delta { expr, negate } => {
                                    if !negate
                                        && expr_nonneg(expr, p.params(), &tpl.nonneg_params)
                                    {
                                        DKind::SafeDelta
                                    } else {
                                        DKind::Delta
                                    }
                                }
                                SetOp::Assign(_) => DKind::Assign,
                            };
                            (*ci, kind)
                        })
                        .collect();
                    WriteShape::Update { cols }
                }
            },
            Err(_) => WriteShape::Unknown,
        };
        out.insert(name.clone(), shape);
    }
    out
}

/// Decide the discharge rule for one write-entry pair. `attrs0`/`attrs1`
/// are the entries' written attributes; side 0 is the candidate.
fn pair_rule(
    shape0: Option<&WriteShape>,
    shape1: Option<&WriteShape>,
    attrs0: &[AttrId],
    attrs1: &[AttrId],
    schema: &Schema,
) -> PairRule {
    let (s0, s1) = match (shape0, shape1) {
        (Some(a), Some(b)) => (a, b),
        _ => return PairRule::NeedsCoverage,
    };
    if matches!(s0, WriteShape::Delete | WriteShape::Unknown)
        || matches!(s1, WriteShape::Delete | WriteShape::Unknown)
    {
        return PairRule::NeedsCoverage;
    }
    if matches!(s0, WriteShape::Insert) || matches!(s1, WriteShape::Insert) {
        return PairRule::InsertFresh;
    }
    let (WriteShape::Update { cols: c0 }, WriteShape::Update { cols: c1 }) = (s0, s1) else {
        return PairRule::NeedsCoverage;
    };
    // Both UPDATEs: every shared attribute must be delta-vs-delta, and on
    // a NonNegative column the candidate's delta must be non-decreasing.
    for a in attrs0 {
        if !attrs1.contains(a) {
            continue;
        }
        let k0 = c0.get(&a.col);
        let k1 = c1.get(&a.col);
        let (Some(k0), Some(k1)) = (k0, k1) else {
            return PairRule::NeedsCoverage;
        };
        if *k0 == DKind::Assign || *k1 == DKind::Assign {
            return PairRule::NeedsCoverage;
        }
        if schema.table(a.table).nonneg(a.col) && *k0 != DKind::SafeDelta {
            return PairRule::NeedsCoverage;
        }
    }
    PairRule::Mergeable
}

/// Fresh-key rule: the clause pins the same `Unique` attribute on both
/// sides with equality on an input parameter. Constants and opaque
/// values do not qualify — freshness cannot be argued for them.
fn clause_unique_pinned(clause: &SClause, schema: &Schema) -> bool {
    clause.0.iter().any(|a| {
        a.op == CmpOp::Eq
            && matches!(&a.rhs, SidedRhs::Param { side: 0, .. })
            && schema.table(a.attr.table).unique(a.attr.col)
            && clause.0.iter().any(|b| {
                b.attr == a.attr
                    && b.op == CmpOp::Eq
                    && matches!(&b.rhs, SidedRhs::Param { side: 1, .. })
            })
    })
}

/// Promote every `Global` / `LocalGlobal` transaction whose remaining
/// write-write conflicts are all provably mergeable (or still covered by
/// routing) to [`OpClass::Confluent`]. Routing parameters are left
/// untouched: a confluent operation routes to its home server exactly
/// like a local one (first routing parameter).
///
/// Must run *before* any [`Classification::force_global`] call — forcing
/// expresses an application-level demand for total ordering that the
/// pass must not undo (the workload constructors respect this ordering).
pub fn reclassify(
    templates: &[TxnTemplate],
    schema: &Schema,
    rwsets: &[RwSets],
    cls: &mut Classification,
) {
    let n = templates.len();
    let profiles: Vec<HashMap<String, WriteShape>> =
        templates.iter().map(|t| profile(t, schema)).collect();

    for t in 0..n {
        if !matches!(cls.classes[t], OpClass::Global | OpClass::LocalGlobal) {
            continue;
        }
        // Weak-read searches are forced global by the workloads; never
        // candidates. A transaction with no writes or no routing anchor
        // has nothing to merge or nowhere deterministic to live.
        if templates[t].weak_reads
            || rwsets[t].writes.is_empty()
            || cls.routing_params[t].is_empty()
        {
            continue;
        }

        let confluent = (0..n).all(|t2| {
            rwsets[t].writes.iter().all(|w0| {
                rwsets[t2].writes.iter().all(|w1| {
                    if !attrs_intersect(&w0.attrs, &w1.attrs) {
                        return true;
                    }
                    let rule = pair_rule(
                        profiles[t].get(&w0.stmt),
                        profiles[t2].get(&w1.stmt),
                        &w0.attrs,
                        &w1.attrs,
                        schema,
                    );
                    pair_condition(w0, w1).0.iter().all(|clause| match rule {
                        PairRule::Mergeable => true,
                        PairRule::InsertFresh => {
                            clause_unique_pinned(clause, schema)
                                || covered(clause, t, t2, templates, cls)
                        }
                        PairRule::NeedsCoverage => covered(clause, t, t2, templates, cls),
                    })
                })
            })
        });

        if confluent {
            cls.classes[t] = OpClass::Confluent;
        }
    }
}

/// Routing coverage, quantified over *every* routing parameter of the
/// candidate (so the decision does not depend on which parameter the
/// runtime happens to route by) paired with *some* parameter of the peer.
fn covered(
    clause: &SClause,
    t: usize,
    t2: usize,
    templates: &[TxnTemplate],
    cls: &Classification,
) -> bool {
    !cls.routing_params[t].is_empty()
        && cls.routing_params[t].iter().all(|&k0| {
            cls.routing_params[t2].iter().any(|&k1| {
                clause.covered_by(&templates[t].params[k0], &templates[t2].params[k1])
            })
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::conflict::ConflictMatrix;
    use crate::analysis::elim::EliminationTensor;
    use crate::analysis::partition::{optimize, PartitionOptions};
    use crate::analysis::rwsets::{extract_rwsets, ExtractOptions};
    use crate::catalog::{TableSchema, ValueType};

    fn analyze(templates: Vec<TxnTemplate>, schema: Schema) -> (Classification, Vec<RwSets>) {
        let rws: Vec<_> = templates
            .iter()
            .map(|t| extract_rwsets(t, &schema, ExtractOptions::default()))
            .collect();
        let matrix = ConflictMatrix::detect(&rws);
        let tensor = EliminationTensor::build(&templates, &matrix);
        let p = optimize(&tensor, &PartitionOptions::default());
        let mut cls = crate::analysis::classify::classify(&templates, &matrix, &p);
        reclassify(&templates, &schema, &rws, &mut cls);
        (cls, rws)
    }

    fn stock_schema(nonneg: bool) -> Schema {
        let mut t = TableSchema::new(
            "STOCK",
            &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
            &["ITEM"],
        );
        if nonneg {
            t = t.with_nonnegative("LEVEL");
        }
        Schema::new(vec![t])
    }

    /// Restock through a derived (opaque) key: uncoverable ww, so the
    /// conflict-only classifier says Global — but both sides are safe
    /// deltas on a NonNegative column, so the pass proves it confluent.
    fn restock() -> TxnTemplate {
        TxnTemplate::new(
            "restock",
            &["q"],
            &[("u", "UPDATE STOCK SET LEVEL = LEVEL + ?q WHERE ITEM = ?derived_item")],
            1.0,
        )
        .with_nonneg_param("q")
    }

    #[test]
    fn safe_delta_global_becomes_confluent() {
        let (cls, _) = analyze(vec![restock()], stock_schema(true));
        assert_eq!(cls.classes[0], OpClass::Confluent);
    }

    #[test]
    fn undeclared_increment_param_blocks_promotion() {
        // Same statement, but the workload does not promise q >= 0: the
        // delta may decrease a NonNegative column, so it must coordinate.
        let tpl = TxnTemplate::new(
            "restock",
            &["q"],
            &[("u", "UPDATE STOCK SET LEVEL = LEVEL + ?q WHERE ITEM = ?derived_item")],
            1.0,
        );
        let (cls, _) = analyze(vec![tpl], stock_schema(true));
        assert_eq!(cls.classes[0], OpClass::Global);
    }

    #[test]
    fn decrement_on_nonnegative_column_stays_global() {
        let tpl = TxnTemplate::new(
            "drain",
            &["q"],
            &[("u", "UPDATE STOCK SET LEVEL = LEVEL - ?q WHERE ITEM = ?derived_item")],
            1.0,
        )
        .with_nonneg_param("q");
        let (cls, _) = analyze(vec![tpl], stock_schema(true));
        assert_eq!(cls.classes[0], OpClass::Global);
    }

    #[test]
    fn unconstrained_column_merges_any_delta() {
        // No invariant declared on LEVEL: plain deltas (either sign)
        // commute and nothing can be violated.
        let tpl = TxnTemplate::new(
            "drain",
            &["q"],
            &[("u", "UPDATE STOCK SET LEVEL = LEVEL - ?q WHERE ITEM = ?derived_item")],
            1.0,
        );
        let (cls, _) = analyze(vec![tpl], stock_schema(false));
        assert_eq!(cls.classes[0], OpClass::Confluent);
    }

    #[test]
    fn assignment_writer_stays_global() {
        let tpl = TxnTemplate::new(
            "reprice",
            &["v"],
            &[("u", "UPDATE STOCK SET LEVEL = ?v WHERE ITEM = ?derived_item")],
            1.0,
        );
        let (cls, _) = analyze(vec![tpl], stock_schema(false));
        assert_eq!(cls.classes[0], OpClass::Global);
    }

    fn reg_schema(unique: bool) -> Schema {
        let mut items = TableSchema::new(
            "ITEMS",
            &[("I_ID", ValueType::Int), ("SELLER", ValueType::Int)],
            &["I_ID"],
        );
        if unique {
            items = items.with_unique("I_ID");
        }
        Schema::new(vec![
            items,
            TableSchema::new(
                "USERS",
                &[("U_ID", ValueType::Int), ("N_ITEMS", ValueType::Int)],
                &["U_ID"],
            ),
        ])
    }

    /// RUBiS-style registerItem: a fresh-key INSERT keyed by item plus a
    /// counter delta keyed by user — LocalGlobal under conflict-only
    /// classification, confluent once I_ID is declared Unique.
    fn register() -> TxnTemplate {
        TxnTemplate::new(
            "registerItem",
            &["iid", "uid"],
            &[
                ("ins", "INSERT INTO ITEMS (I_ID, SELLER) VALUES (?iid, ?uid)"),
                ("cnt", "UPDATE USERS SET N_ITEMS = N_ITEMS + 1 WHERE U_ID = ?uid"),
            ],
            1.0,
        )
    }

    #[test]
    fn unique_insert_turns_local_global_into_confluent() {
        let (cls, _) = analyze(vec![register()], reg_schema(true));
        assert_eq!(cls.classes[0], OpClass::Confluent);
        // Routing is untouched: the double-key set survives, and the
        // runtime routes by its first entry.
        assert_eq!(cls.routing_params[0].len(), 2);
    }

    #[test]
    fn without_unique_declaration_insert_needs_agreement() {
        let (cls, _) = analyze(vec![register()], reg_schema(false));
        assert_eq!(cls.classes[0], OpClass::LocalGlobal);
    }

    #[test]
    fn local_and_commutative_are_never_touched() {
        let schema = stock_schema(true);
        let local = TxnTemplate::new(
            "touch",
            &["i", "q"],
            &[("u", "UPDATE STOCK SET LEVEL = LEVEL + ?q WHERE ITEM = ?i")],
            1.0,
        )
        .with_nonneg_param("q");
        let (cls, _) = analyze(vec![local], schema);
        assert_eq!(cls.classes[0], OpClass::Local);
    }
}
