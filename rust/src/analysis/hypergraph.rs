//! Hypergraph-cut scorer: the per-*template* objective behind live
//! routing epochs.
//!
//! The scalar reference ([`super::score`]) charges every surviving
//! conflicting *pair* — Algorithm 1's quadratic objective. Hypergraph
//! partitioners ("Hyper-Graph Based Database Partitioning for
//! Transactional Workloads") charge each *transaction* hyperedge once,
//! as soon as any of its conflicts crosses the cut. That matches how the
//! runtime actually pays: a template with *any* uncovered conflict under
//! assignment `P` executes under the token (Global) and pays its full
//! traffic share, no matter how many distinct pairs break it.
//!
//! `cost_H(P) = Σ_t w(t) · [∃ t' : conflict(t,t') not eliminated under
//! (P[t], P[t'])]`
//!
//! With `w(t)` set to a template's observed operation rate, `cost_H(P)`
//! is exactly the belted traffic fraction the pinned epoch classifier
//! ([`super::drift::pin_classes`]) would produce under `P` — so the
//! epoch controller's observed-vs-optimal comparison is apples to
//! apples.

use super::elim::EliminationTensor;
use super::score::{Assignment, BatchScorer};
use crate::workload::spec::TxnTemplate;

/// Is the `(t, t2)` conflict eliminated under `assign`? Symmetric access
/// normalized onto the tensor's upper triangle; `None` choices never
/// eliminate.
pub fn pair_eliminated(
    tensor: &EliminationTensor,
    t: usize,
    t2: usize,
    assign: &Assignment,
) -> bool {
    let (a, b) = if t <= t2 { (t, t2) } else { (t2, t) };
    match (assign[a], assign[b]) {
        (Some(k), Some(k2)) => tensor.eliminated(a, b, k, k2),
        _ => false,
    }
}

/// Does template `t` survive assignment `assign` with *every* one of its
/// conflicts eliminated? (Templates without conflicts trivially do.)
pub fn template_covered(tensor: &EliminationTensor, t: usize, assign: &Assignment) -> bool {
    (0..tensor.n).all(|t2| {
        let linked = if t <= t2 { tensor.conflict[t][t2] } else { tensor.conflict[t2][t] };
        !linked || pair_eliminated(tensor, t, t2, assign)
    })
}

/// The hypergraph scorer: per-template all-or-nothing hyperedge cost.
///
/// Unlike [`super::score::ScalarScorer`] this does *not* equal
/// [`super::score::cost_batch`] — it is the refined objective the epoch
/// controller optimizes (see the module docs).
pub struct HypergraphScorer {
    /// Per-template hyperedge weight (typically the observed operation
    /// rate, or the static template weight).
    pub weights: Vec<f64>,
}

impl HypergraphScorer {
    pub fn new(weights: Vec<f64>) -> Self {
        HypergraphScorer { weights }
    }

    /// Static-analysis construction: hyperedge weights from the declared
    /// template weights.
    pub fn from_templates(templates: &[TxnTemplate]) -> Self {
        HypergraphScorer { weights: templates.iter().map(|t| t.weight).collect() }
    }

    /// Score a single assignment.
    pub fn cut(&self, tensor: &EliminationTensor, assign: &Assignment) -> f64 {
        debug_assert_eq!(self.weights.len(), tensor.n);
        debug_assert_eq!(assign.len(), tensor.n);
        (0..tensor.n)
            .filter(|&t| !template_covered(tensor, t, assign))
            .map(|t| self.weights[t])
            .sum()
    }
}

impl BatchScorer for HypergraphScorer {
    fn score(&self, tensor: &EliminationTensor, batch: &[Assignment]) -> Vec<f64> {
        batch.iter().map(|a| self.cut(tensor, a)).collect()
    }

    fn name(&self) -> &'static str {
        "hypergraph"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::conflict::ConflictMatrix;
    use crate::analysis::partition::{optimize, PartitionOptions};
    use crate::analysis::rwsets::{extract_rwsets, ExtractOptions};
    use crate::catalog::{Schema, TableSchema, ValueType};
    use std::sync::Arc;

    fn cart() -> (Vec<TxnTemplate>, EliminationTensor) {
        let schema = Schema::new(vec![TableSchema::new(
            "SC",
            &[("ID", ValueType::Int), ("I_ID", ValueType::Int), ("QTY", ValueType::Int)],
            &["ID", "I_ID"],
        )]);
        let templates = vec![
            TxnTemplate::new(
                "createCart",
                &["sid"],
                &[("i", "INSERT INTO SC (ID, I_ID, QTY) VALUES (?sid, 0, 0)")],
                1.0,
            ),
            TxnTemplate::new(
                "doCart",
                &["sid", "iid", "q"],
                &[("u", "UPDATE SC SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid")],
                2.0,
            ),
        ];
        let rws: Vec<_> = templates
            .iter()
            .map(|t| extract_rwsets(t, &schema, ExtractOptions::default()))
            .collect();
        let tensor = EliminationTensor::build(&templates, &ConflictMatrix::detect(&rws));
        (templates, tensor)
    }

    #[test]
    fn fully_covered_assignment_costs_zero() {
        let (tpls, t) = cart();
        let s = HypergraphScorer::from_templates(&tpls);
        assert_eq!(s.cut(&t, &vec![Some(0), Some(0)]), 0.0);
    }

    #[test]
    fn each_broken_template_pays_once() {
        let (tpls, t) = cart();
        let s = HypergraphScorer::from_templates(&tpls);
        // doCart on iid: the (createCart, doCart) pair survives, breaking
        // BOTH hyperedges — but each pays its own weight exactly once.
        assert_eq!(s.cut(&t, &vec![Some(0), Some(1)]), 3.0);
        // No assignment at all: every conflicting template pays.
        assert_eq!(s.cut(&t, &vec![None, None]), 3.0);
    }

    #[test]
    fn optimizer_accepts_the_hypergraph_objective() {
        let (tpls, t) = cart();
        let opts = PartitionOptions {
            scorer: Arc::new(HypergraphScorer::from_templates(&tpls)),
            ..Default::default()
        };
        let p = optimize(&t, &opts);
        assert_eq!(p.cost, 0.0);
        assert_eq!(p.choice, vec![Some(0), Some(0)]); // both on sid
    }

    #[test]
    fn covered_matches_pairwise_structure() {
        let (_, t) = cart();
        // Both on sid: every conflict eliminated, both templates covered.
        let good = vec![Some(0), Some(0)];
        assert!(template_covered(&t, 0, &good));
        assert!(template_covered(&t, 1, &good));
        // doCart pinned on iid: its self-conflict is covered (iid=iid'
        // appears in the clause) but the cross pair with createCart
        // survives — so BOTH templates lose coverage.
        let mixed = vec![Some(0), Some(1)];
        assert!(pair_eliminated(&t, 1, 1, &mixed));
        assert!(!template_covered(&t, 0, &mixed));
        assert!(!template_covered(&t, 1, &mixed));
    }
}
