//! The elimination tensor: a dense encoding of "which partitioning
//! parameter choices make which conflicts local".
//!
//! `elim[t][t'][k][k'] = 1` iff *every* satisfiable clause of the
//! combined conflict condition between `t` and `t'` is covered when `t`
//! partitions on its `k`-th parameter and `t'` on its `k'`-th. The
//! Algorithm 1 cost of a partitioning array `P` is then the pure tensor
//! contraction implemented both by [`super::score`] (scalar reference)
//! and by the AOT-compiled Pallas kernel (batched, via
//! [`crate::runtime::CostEvaluator`]).
//!
//! Only the upper triangle `t <= t'` is populated, so summing over all
//! ordered pairs counts each unordered conflict exactly once.

use super::conflict::ConflictMatrix;
use crate::workload::spec::TxnTemplate;

#[derive(Debug, Clone)]
pub struct EliminationTensor {
    /// Number of transactions.
    pub n: usize,
    /// Number of candidate partitioning parameters per transaction.
    pub kdims: Vec<usize>,
    /// max(kdims, 1) — the padded K dimension.
    pub kmax: usize,
    /// `conflict[t][t']` (t <= t' only): an unavoidable conflict exists.
    pub conflict: Vec<Vec<bool>>,
    /// `w2[t][t'] = weight(t) + weight(t')` (t <= t' only).
    pub w2: Vec<Vec<f64>>,
    /// Flattened `[n][n][kmax][kmax]` coverage bits.
    elim: Vec<bool>,
}

impl EliminationTensor {
    fn idx(&self, t: usize, t2: usize, k: usize, k2: usize) -> usize {
        ((t * self.n + t2) * self.kmax + k) * self.kmax + k2
    }

    /// Is the `(t, t')` conflict eliminated when `P[t]=k`, `P[t']=k'`?
    /// (`t <= t'` expected; symmetric access is normalized by the caller.)
    pub fn eliminated(&self, t: usize, t2: usize, k: usize, k2: usize) -> bool {
        self.elim[self.idx(t, t2, k, k2)]
    }

    /// Build the tensor from templates and the conflict matrix.
    pub fn build(templates: &[TxnTemplate], matrix: &ConflictMatrix) -> Self {
        let n = templates.len();
        assert_eq!(n, matrix.n);
        let kdims: Vec<usize> = templates.iter().map(|t| t.params.len()).collect();
        let kmax = kdims.iter().copied().max().unwrap_or(0).max(1);
        let mut tensor = EliminationTensor {
            n,
            kdims: kdims.clone(),
            kmax,
            conflict: vec![vec![false; n]; n],
            w2: vec![vec![0.0; n]; n],
            elim: vec![false; n * n * kmax * kmax],
        };
        for t in 0..n {
            for t2 in t..n {
                let combined = matrix.combined(t, t2);
                if combined.is_false() {
                    continue;
                }
                tensor.conflict[t][t2] = true;
                tensor.w2[t][t2] = templates[t].weight + templates[t2].weight;
                for k in 0..kdims[t] {
                    for k2 in 0..kdims[t2] {
                        let covered = !combined
                            .uncovered(Some(&templates[t].params[k]), Some(&templates[t2].params[k2]));
                        let i = tensor.idx(t, t2, k, k2);
                        tensor.elim[i] = covered;
                    }
                }
            }
        }
        tensor
    }

    /// Total number of conflicting (unordered) pairs.
    pub fn conflict_pairs(&self) -> usize {
        self.conflict.iter().flatten().filter(|&&c| c).count()
    }

    /// Export the dense f32 buffers the AOT kernel consumes:
    /// `(cw[t*n+t2] = conflict*w2, elim[(t,t2,k,k2)])`, both padded to
    /// `(t_pad, t_pad, k_pad, k_pad)`.
    pub fn to_f32(&self, t_pad: usize, k_pad: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(t_pad >= self.n && k_pad >= self.kmax, "padding too small");
        let mut cw = vec![0f32; t_pad * t_pad];
        let mut elim = vec![0f32; t_pad * t_pad * k_pad * k_pad];
        for t in 0..self.n {
            for t2 in t..self.n {
                if !self.conflict[t][t2] {
                    continue;
                }
                cw[t * t_pad + t2] = self.w2[t][t2] as f32;
                for k in 0..self.kmax {
                    for k2 in 0..self.kmax {
                        if self.eliminated(t, t2, k, k2) {
                            let i = ((t * t_pad + t2) * k_pad + k) * k_pad + k2;
                            elim[i] = 1.0;
                        }
                    }
                }
            }
        }
        (cw, elim)
    }

    /// Connected components of the conflict graph (transactions linked by
    /// a conflict). Partitioning parameters can be optimized per component.
    pub fn components(&self) -> Vec<Vec<usize>> {
        let mut comp = vec![usize::MAX; self.n];
        let mut next = 0;
        for start in 0..self.n {
            if comp[start] != usize::MAX {
                continue;
            }
            let mut stack = vec![start];
            comp[start] = next;
            while let Some(t) = stack.pop() {
                for t2 in 0..self.n {
                    let linked = if t <= t2 { self.conflict[t][t2] } else { self.conflict[t2][t] };
                    if linked && comp[t2] == usize::MAX {
                        comp[t2] = next;
                        stack.push(t2);
                    }
                }
            }
            next += 1;
        }
        let mut out = vec![Vec::new(); next];
        for (t, &c) in comp.iter().enumerate() {
            out[c].push(t);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::rwsets::{extract_rwsets, ExtractOptions};
    use crate::catalog::{Schema, TableSchema, ValueType};

    fn schema() -> Schema {
        Schema::new(vec![
            TableSchema::new(
                "SC",
                &[("ID", ValueType::Int), ("I_ID", ValueType::Int), ("QTY", ValueType::Int)],
                &["ID", "I_ID"],
            ),
            TableSchema::new("LOG", &[("ID", ValueType::Int), ("M", ValueType::Str)], &["ID"]),
        ])
    }

    fn cart_app() -> Vec<TxnTemplate> {
        vec![
            TxnTemplate::new(
                "createCart",
                &["sid"],
                &[("i", "INSERT INTO SC (ID, I_ID, QTY) VALUES (?sid, 0, 0)")],
                1.0,
            ),
            TxnTemplate::new(
                "doCart",
                &["sid", "iid", "q"],
                &[("u", "UPDATE SC SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid")],
                2.0,
            ),
            TxnTemplate::new(
                "audit",
                &["lid"],
                &[("i", "INSERT INTO LOG (ID, M) VALUES (?lid, 'x')")],
                0.5,
            ),
        ]
    }

    fn tensor(templates: &[TxnTemplate]) -> EliminationTensor {
        let rws: Vec<_> = templates
            .iter()
            .map(|t| extract_rwsets(t, &schema(), ExtractOptions::default()))
            .collect();
        let m = ConflictMatrix::detect(&rws);
        EliminationTensor::build(templates, &m)
    }

    #[test]
    fn cart_pair_elimination_on_sid() {
        let templates = cart_app();
        let t = tensor(&templates);
        assert!(t.conflict[0][1]);
        // createCart param 0 = sid, doCart param 0 = sid: eliminated.
        assert!(t.eliminated(0, 1, 0, 0));
        // doCart partitioned on iid (param 1): not eliminated.
        assert!(!t.eliminated(0, 1, 0, 1));
        // Weight of the pair is 1.0 + 2.0.
        assert!((t.w2[0][1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn self_conflicts_on_diagonal() {
        let templates = cart_app();
        let t = tensor(&templates);
        // createCart self-conflict (two inserts may share sid), eliminated
        // when both route on sid.
        assert!(t.conflict[0][0]);
        assert!(t.eliminated(0, 0, 0, 0));
        // audit (LOG insert) self-conflicts, eliminated on lid.
        assert!(t.conflict[2][2]);
        assert!(t.eliminated(2, 2, 0, 0));
    }

    #[test]
    fn components_split_disjoint_tables() {
        let templates = cart_app();
        let t = tensor(&templates);
        let comps = t.components();
        assert_eq!(comps.len(), 2);
        assert!(comps.iter().any(|c| c == &vec![0, 1]));
        assert!(comps.iter().any(|c| c == &vec![2]));
    }

    /// A tensor with an arbitrary upper-triangular conflict relation and
    /// no coverage information — all `components` looks at.
    fn tensor_with_edges(n: usize, edges: &[(usize, usize)]) -> EliminationTensor {
        let mut conflict = vec![vec![false; n]; n];
        for &(a, b) in edges {
            let (t, t2) = if a <= b { (a, b) } else { (b, a) };
            conflict[t][t2] = true;
        }
        EliminationTensor {
            n,
            kdims: vec![1; n],
            kmax: 1,
            conflict,
            w2: vec![vec![0.0; n]; n],
            elim: vec![false; n * n],
        }
    }

    #[test]
    fn qcheck_components_partition_the_transaction_set() {
        use crate::util::qcheck::{check, Config};
        use crate::util::Rng;

        fn gen_edges(rng: &mut Rng, n: usize) -> Vec<(usize, usize)> {
            let mut edges = Vec::new();
            for t in 0..n {
                for t2 in t..n {
                    if rng.chance(0.2) {
                        edges.push((t, t2));
                    }
                }
            }
            edges
        }

        check(Config::default().cases(200).name("components-partition"), |rng| {
            let n = rng.range(1, 12);
            let edges = gen_edges(rng, n);
            let tensor = tensor_with_edges(n, &edges);
            let comps = tensor.components();

            // (a) Exact partition: every transaction in exactly one part.
            let mut owner = vec![None; n];
            for (c, comp) in comps.iter().enumerate() {
                assert!(!comp.is_empty(), "empty component emitted");
                for &t in comp {
                    assert!(owner[t].is_none(), "txn {t} appears in two components");
                    owner[t] = Some(c);
                }
            }
            assert!(owner.iter().all(|o| o.is_some()), "txn missing from all components");

            // (b) No conflict edge crosses components.
            for &(t, t2) in &edges {
                assert_eq!(owner[t], owner[t2], "edge ({t},{t2}) crosses components");
            }

            // (c) Each part is internally connected: BFS over the edge
            // list from its first member reaches every other member.
            let neighbours = |t: usize| -> Vec<usize> {
                edges
                    .iter()
                    .filter_map(|&(a, b)| {
                        if a == t {
                            Some(b)
                        } else if b == t {
                            Some(a)
                        } else {
                            None
                        }
                    })
                    .collect()
            };
            for comp in &comps {
                let mut seen = vec![false; n];
                let mut queue = vec![comp[0]];
                seen[comp[0]] = true;
                while let Some(t) = queue.pop() {
                    for t2 in neighbours(t) {
                        if !seen[t2] {
                            seen[t2] = true;
                            queue.push(t2);
                        }
                    }
                }
                for &t in comp {
                    assert!(seen[t], "component {comp:?} is not connected at {t}");
                }
            }
        });
    }

    #[test]
    fn f32_export_pads_and_matches() {
        let templates = cart_app();
        let t = tensor(&templates);
        let (cw, elim) = t.to_f32(8, 4);
        assert_eq!(cw.len(), 64);
        assert_eq!(elim.len(), 8 * 8 * 4 * 4);
        // cw[0][1] = 3.0
        assert_eq!(cw[1], 3.0);
        // Lower triangle empty.
        assert_eq!(cw[8], 0.0);
        // elim(0,1,0,0) set.
        let i = ((0 * 8 + 1) * 4 + 0) * 4 + 0;
        assert_eq!(elim[i], 1.0);
    }
}
