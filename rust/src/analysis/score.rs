//! The scalar reference scorer for Algorithm 1's cost function.
//!
//! `cost(P) = Σ_{t ≤ t'} conflict[t,t'] · (w(t)+w(t')) ·
//!            [conflict not eliminated under (P[t], P[t'])]`
//!
//! This is the semantic ground truth; the AOT-compiled Pallas kernel
//! computes the identical quantity in batch and is cross-checked against
//! this function in tests (`rust/tests/cost_parity.rs`).

use super::elim::EliminationTensor;

/// A partitioning assignment: for each transaction, the index of its
/// partitioning parameter (`None` = transaction has no usable parameter).
pub type Assignment = Vec<Option<usize>>;

/// Score one assignment.
pub fn cost(tensor: &EliminationTensor, assign: &Assignment) -> f64 {
    debug_assert_eq!(assign.len(), tensor.n);
    let mut total = 0.0;
    for t in 0..tensor.n {
        for t2 in t..tensor.n {
            if !tensor.conflict[t][t2] {
                continue;
            }
            let eliminated = match (assign[t], assign[t2]) {
                (Some(k), Some(k2)) => tensor.eliminated(t, t2, k, k2),
                _ => false,
            };
            if !eliminated {
                total += tensor.w2[t][t2];
            }
        }
    }
    total
}

/// Score a batch of assignments (the scalar counterpart of the AOT
/// artifact's batched evaluation).
pub fn cost_batch(tensor: &EliminationTensor, batch: &[Assignment]) -> Vec<f64> {
    batch.iter().map(|a| cost(tensor, a)).collect()
}

/// Trait for pluggable batch scorers.
pub trait BatchScorer: Send + Sync {
    /// Score `batch`. Implementations that *evaluate* Algorithm 1's
    /// objective (the scalar reference here, the AOT Pallas kernel) must
    /// equal [`cost_batch`] on every input; implementations may instead
    /// *refine* the objective (e.g. the per-template hyperedge cut of
    /// [`crate::analysis::hypergraph::HypergraphScorer`]) — the
    /// optimizer minimizes whatever the scorer reports.
    fn score(&self, tensor: &EliminationTensor, batch: &[Assignment]) -> Vec<f64>;
    fn name(&self) -> &'static str;
}

/// The default scorer: the scalar reference.
pub struct ScalarScorer;

impl BatchScorer for ScalarScorer {
    fn score(&self, tensor: &EliminationTensor, batch: &[Assignment]) -> Vec<f64> {
        cost_batch(tensor, batch)
    }

    fn name(&self) -> &'static str {
        "scalar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::conflict::ConflictMatrix;
    use crate::analysis::rwsets::{extract_rwsets, ExtractOptions};
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::workload::spec::TxnTemplate;

    fn tensor() -> EliminationTensor {
        let schema = Schema::new(vec![TableSchema::new(
            "SC",
            &[("ID", ValueType::Int), ("I_ID", ValueType::Int), ("QTY", ValueType::Int)],
            &["ID", "I_ID"],
        )]);
        let templates = vec![
            TxnTemplate::new(
                "createCart",
                &["sid"],
                &[("i", "INSERT INTO SC (ID, I_ID, QTY) VALUES (?sid, 0, 0)")],
                1.0,
            ),
            TxnTemplate::new(
                "doCart",
                &["sid", "iid", "q"],
                &[("u", "UPDATE SC SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid")],
                2.0,
            ),
        ];
        let rws: Vec<_> = templates
            .iter()
            .map(|t| extract_rwsets(t, &schema, ExtractOptions::default()))
            .collect();
        EliminationTensor::build(&templates, &ConflictMatrix::detect(&rws))
    }

    #[test]
    fn best_assignment_costs_zero() {
        let t = tensor();
        // Both partition on sid: all three conflicts (0-0, 0-1, 1-1)
        // eliminated.
        assert_eq!(cost(&t, &vec![Some(0), Some(0)]), 0.0);
    }

    #[test]
    fn bad_assignment_pays_weights() {
        let t = tensor();
        // doCart on iid: pair (0,1) costs 3.0; self (1,1) on (iid,iid):
        // the WW self-conflict of doCart requires sid=sid' AND iid=iid'
        // in its clause, so iid/iid covers it... check both plausible
        // outcomes by computing explicitly.
        let c = cost(&t, &vec![Some(0), Some(1)]);
        // (0,0) self eliminated via sid; (0,1) pays 3.0; (1,1) covered by
        // iid=iid' (the clause contains I_ID = iid on both sides).
        assert_eq!(c, 3.0);
    }

    #[test]
    fn none_assignment_pays_everything() {
        let t = tensor();
        let all = cost(&t, &vec![None, None]);
        // Pairs: (0,0) w=2, (0,1) w=3, (1,1) w=4 => 9 total.
        assert_eq!(all, 9.0);
    }

    #[test]
    fn batch_matches_single() {
        let t = tensor();
        let batch = vec![
            vec![Some(0), Some(0)],
            vec![Some(0), Some(1)],
            vec![None, None],
        ];
        assert_eq!(cost_batch(&t, &batch), vec![0.0, 3.0, 9.0]);
    }
}
