//! Pairwise conflict detection — the first phase of Algorithm 1.
//!
//! For every ordered pair of transactions `(t, t')` we build, per conflict
//! kind, a condition in DNF over *sided* atoms that the input parameters
//! of the two transactions must satisfy for operations of `t` and `t'` to
//! conflict on the same row(s). Side 0 refers to `t`'s parameters, side 1
//! to `t'`'s (the paper's `sid` vs `sid'` priming).

use super::rwsets::{AccessEntry, AttrId, Dnf, Rhs, RwSets};
use crate::sqlir::{CmpOp, Literal};

/// Conflict kinds, ordered pair semantics:
/// * `WW` — a write of `t` and a write of `t'` overlap,
/// * `WR` — a write of `t` overlaps a read of `t'` (i.e. *`t'` reads from
///   `t`* in the paper's terminology).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ConflictKind {
    WW,
    WR,
}

/// The RHS of a sided atom.
#[derive(Debug, Clone, PartialEq)]
pub enum SidedRhs {
    /// Parameter `name` of the transaction on `side` (0 = t, 1 = t').
    Param { side: u8, name: String },
    Const(Literal),
    Opaque,
}

/// `attr op rhs` with side-tagged parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct SidedAtom {
    pub attr: AttrId,
    pub op: CmpOp,
    pub rhs: SidedRhs,
}

/// Conjunction of sided atoms.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SClause(pub Vec<SidedAtom>);

impl SClause {
    /// A clause is *covered* by the partitioning parameter choice
    /// `(k0, k1)` if it contains equality atoms binding the same attribute
    /// to parameter `k0` of side 0 and `k1` of side 1 — then the shared
    /// deterministic routing function sends both conflicting operations to
    /// the same server and the conflict is local (paper §3.1, the
    /// `(k = A ∧ k' = A ∧ …)` clause-removal rule).
    pub fn covered_by(&self, k0: &str, k1: &str) -> bool {
        self.0.iter().any(|a| {
            a.op == CmpOp::Eq
                && matches!(&a.rhs, SidedRhs::Param { side: 0, name } if name == k0)
                && self.0.iter().any(|b| {
                    b.attr == a.attr
                        && b.op == CmpOp::Eq
                        && matches!(&b.rhs, SidedRhs::Param { side: 1, name } if name == k1)
                })
        })
    }

    /// Conservative satisfiability: detect contradictions between
    /// *constant* constraints on the same attribute. Parameters and
    /// opaque values never contradict (they can take any value).
    pub fn satisfiable(&self) -> bool {
        // Group constant constraints per attribute.
        let mut attrs: Vec<AttrId> = self.0.iter().map(|a| a.attr).collect();
        attrs.sort_unstable();
        attrs.dedup();
        for attr in attrs {
            let consts: Vec<(&CmpOp, &Literal)> = self
                .0
                .iter()
                .filter(|a| a.attr == attr)
                .filter_map(|a| match &a.rhs {
                    SidedRhs::Const(l) => Some((&a.op, l)),
                    _ => None,
                })
                .collect();
            if consts.is_empty() {
                continue;
            }
            if !consts_satisfiable(&consts) {
                return false;
            }
        }
        true
    }
}

fn lit_f64(l: &Literal) -> Option<f64> {
    match l {
        Literal::Int(i) => Some(*i as f64),
        Literal::Float(x) => Some(*x),
        _ => None,
    }
}

fn lit_eq(a: &Literal, b: &Literal) -> bool {
    match (a, b) {
        (Literal::Str(x), Literal::Str(y)) => x == y,
        _ => match (lit_f64(a), lit_f64(b)) {
            (Some(x), Some(y)) => x == y,
            _ => false,
        },
    }
}

fn consts_satisfiable(consts: &[(&CmpOp, &Literal)]) -> bool {
    // Equalities must all agree.
    let eqs: Vec<&Literal> = consts
        .iter()
        .filter(|(op, _)| **op == CmpOp::Eq)
        .map(|(_, l)| *l)
        .collect();
    for w in eqs.windows(2) {
        if !lit_eq(w[0], w[1]) {
            return false;
        }
    }
    // Numeric range reasoning.
    let mut lo = f64::NEG_INFINITY;
    let mut lo_strict = false;
    let mut hi = f64::INFINITY;
    let mut hi_strict = false;
    for (op, l) in consts {
        let Some(x) = lit_f64(l) else { continue };
        match op {
            CmpOp::Gt => {
                if x >= lo {
                    lo = x;
                    lo_strict = true;
                }
            }
            CmpOp::Ge => {
                if x > lo {
                    lo = x;
                    lo_strict = false;
                }
            }
            CmpOp::Lt => {
                if x <= hi {
                    hi = x;
                    hi_strict = true;
                }
            }
            CmpOp::Le => {
                if x < hi {
                    hi = x;
                    hi_strict = false;
                }
            }
            _ => {}
        }
    }
    if lo > hi || (lo == hi && (lo_strict || hi_strict)) {
        return false;
    }
    // Equality must sit inside the range and not hit a disequality.
    if let Some(eq) = eqs.first() {
        if let Some(x) = lit_f64(eq) {
            if x < lo || x > hi || (x == lo && lo_strict) || (x == hi && hi_strict) {
                return false;
            }
        }
        for (op, l) in consts {
            if **op == CmpOp::Ne && lit_eq(eq, l) {
                return false;
            }
        }
    }
    true
}

/// Disjunction of sided clauses. Empty = no conflict possible.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SDnf(pub Vec<SClause>);

impl SDnf {
    pub fn is_false(&self) -> bool {
        self.0.is_empty()
    }

    pub fn or_with(&mut self, other: SDnf) {
        self.0.extend(other.0);
    }

    /// Whether any clause survives the coverage rule for `(k0, k1)`.
    pub fn uncovered(&self, k0: Option<&str>, k1: Option<&str>) -> bool {
        match (k0, k1) {
            (Some(k0), Some(k1)) => self.0.iter().any(|c| !c.covered_by(k0, k1)),
            _ => !self.0.is_empty(),
        }
    }
}

fn side_atoms(cond: &Dnf, side: u8) -> Vec<SClause> {
    cond.0
        .iter()
        .map(|clause| {
            SClause(
                clause
                    .0
                    .iter()
                    .map(|a| SidedAtom {
                        attr: a.attr,
                        op: a.op,
                        rhs: match &a.rhs {
                            Rhs::Param(p) => SidedRhs::Param { side, name: p.clone() },
                            Rhs::Const(l) => SidedRhs::Const(l.clone()),
                            Rhs::Opaque => SidedRhs::Opaque,
                        },
                    })
                    .collect(),
            )
        })
        .collect()
}

/// Conjoin two entry conditions (side 0 and side 1), keeping only
/// satisfiable clauses. Also used by `analysis::confluence`, which
/// re-derives ww conditions per *entry pair* (the matrix only keeps the
/// per-template union) to decide which statements caused each clause.
pub(crate) fn pair_condition(e0: &AccessEntry, e1: &AccessEntry) -> SDnf {
    let c0 = side_atoms(&e0.cond, 0);
    let c1 = side_atoms(&e1.cond, 1);
    let mut out = Vec::new();
    for a in &c0 {
        for b in &c1 {
            let mut atoms = a.0.clone();
            atoms.extend(b.0.iter().cloned());
            let clause = SClause(atoms);
            if clause.satisfiable() {
                out.push(clause);
            }
        }
    }
    SDnf(out)
}

pub(crate) fn attrs_intersect(a: &[AttrId], b: &[AttrId]) -> bool {
    a.iter().any(|x| b.contains(x))
}

/// The full pairwise conflict structure of an application.
#[derive(Debug, Clone)]
pub struct ConflictMatrix {
    pub n: usize,
    /// `ww[t][t']`: write-write condition.
    pub ww: Vec<Vec<SDnf>>,
    /// `wr[t][t']`: `t` writes what `t'` reads (`t'` reads-from `t`).
    pub wr: Vec<Vec<SDnf>>,
}

impl ConflictMatrix {
    /// Run conflict detection over per-transaction read/write sets.
    pub fn detect(rwsets: &[RwSets]) -> ConflictMatrix {
        let n = rwsets.len();
        let mut ww = vec![vec![SDnf::default(); n]; n];
        let mut wr = vec![vec![SDnf::default(); n]; n];
        for t in 0..n {
            for t2 in 0..n {
                // Write-write (computed for ordered pairs; symmetric by
                // construction up to side swap).
                for w0 in &rwsets[t].writes {
                    for w1 in &rwsets[t2].writes {
                        if attrs_intersect(&w0.attrs, &w1.attrs) {
                            ww[t][t2].or_with(pair_condition(w0, w1));
                        }
                    }
                }
                // t writes, t' reads.
                for w0 in &rwsets[t].writes {
                    for r1 in &rwsets[t2].reads {
                        if attrs_intersect(&w0.attrs, &r1.attrs) {
                            wr[t][t2].or_with(pair_condition(w0, r1));
                        }
                    }
                }
            }
        }
        ConflictMatrix { n, ww, wr }
    }

    /// The symmetric "any conflict" condition of the unordered pair, used
    /// by Algorithm 1's cost function: `ww(t,t') ∨ wr(t,t') ∨ wr(t',t)`
    /// with all conditions normalized to side 0 = `t`.
    pub fn combined(&self, t: usize, t2: usize) -> SDnf {
        let mut out = self.ww[t][t2].clone();
        out.or_with(self.wr[t][t2].clone());
        // wr[t2][t] has side 0 = t2; swap sides to normalize.
        let mut swapped = self.wr[t2][t].clone();
        for clause in &mut swapped.0 {
            for atom in &mut clause.0 {
                if let SidedRhs::Param { side, .. } = &mut atom.rhs {
                    *side = 1 - *side;
                }
            }
        }
        out.or_with(swapped);
        out
    }

    /// Does `t` conflict with anything (including itself)?
    pub fn has_any_conflict(&self, t: usize) -> bool {
        (0..self.n).any(|t2| {
            !self.ww[t][t2].is_false()
                || !self.wr[t][t2].is_false()
                || !self.wr[t2][t].is_false()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::rwsets::{extract_rwsets, ExtractOptions};
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::workload::spec::TxnTemplate;

    fn schema() -> Schema {
        Schema::new(vec![
            TableSchema::new(
                "SC",
                &[("ID", ValueType::Int), ("I_ID", ValueType::Int), ("QTY", ValueType::Int)],
                &["ID", "I_ID"],
            ),
            TableSchema::new(
                "LOG",
                &[("ID", ValueType::Int), ("MSG", ValueType::Str)],
                &["ID"],
            ),
        ])
    }

    fn rw(templates: &[TxnTemplate]) -> Vec<crate::analysis::rwsets::RwSets> {
        templates
            .iter()
            .map(|t| extract_rwsets(t, &schema(), ExtractOptions::default()))
            .collect()
    }

    #[test]
    fn paper_example_createcart_docart_ww_conflict() {
        // createCart INSERTs a row (writes all columns incl. QTY); doCart
        // UPDATEs QTY. The WW condition must require SC.ID = sid (side 0)
        // and SC.ID = sid' (side 1) in the same clause — i.e. covered by
        // partitioning both on sid.
        let create = TxnTemplate::new(
            "createCart",
            &["sid"],
            &[("ins", "INSERT INTO SC (ID, I_ID, QTY) VALUES (?sid, 0, 0)")],
            1.0,
        );
        let docart = TxnTemplate::new(
            "doCart",
            &["sid", "iid", "q"],
            &[("upd", "UPDATE SC SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid")],
            1.0,
        );
        let m = ConflictMatrix::detect(&rw(&[create, docart]));
        let cond = &m.ww[0][1];
        assert!(!cond.is_false(), "expected WW conflict");
        // Covered when both partition on sid.
        assert!(!cond.uncovered(Some("sid"), Some("sid")));
        // Not covered when doCart partitions on iid (createCart has no such
        // binding on I_ID... actually createCart binds I_ID = 0, a const).
        assert!(cond.uncovered(Some("sid"), Some("iid")));
    }

    #[test]
    fn disjoint_tables_no_conflict() {
        let a = TxnTemplate::new(
            "cart",
            &["sid"],
            &[("u", "UPDATE SC SET QTY = 1 WHERE ID = ?sid")],
            1.0,
        );
        let b = TxnTemplate::new(
            "log",
            &["id"],
            &[("i", "INSERT INTO LOG (ID, MSG) VALUES (?id, 'x')")],
            1.0,
        );
        let m = ConflictMatrix::detect(&rw(&[a, b]));
        assert!(m.ww[0][1].is_false());
        assert!(m.wr[0][1].is_false());
        assert!(m.wr[1][0].is_false());
        // But LOG inserts self-conflict (two inserts may share a key).
        assert!(!m.ww[1][1].is_false());
    }

    #[test]
    fn wr_direction_is_ordered() {
        let writer = TxnTemplate::new(
            "w",
            &["sid"],
            &[("u", "UPDATE SC SET QTY = 1 WHERE ID = ?sid")],
            1.0,
        );
        let reader = TxnTemplate::new(
            "r",
            &["sid"],
            &[("q", "SELECT QTY FROM SC WHERE ID = ?sid")],
            1.0,
        );
        let m = ConflictMatrix::detect(&rw(&[writer, reader]));
        assert!(!m.wr[0][1].is_false(), "writer->reader WR expected");
        assert!(m.wr[1][0].is_false(), "reader never written-from");
    }

    #[test]
    fn constant_contradiction_prunes_clause() {
        // Writers to disjoint constant key ranges cannot conflict.
        let a = TxnTemplate::new("a", &[], &[("u", "UPDATE SC SET QTY = 1 WHERE ID = 1 AND I_ID = 1")], 1.0);
        let b = TxnTemplate::new("b", &[], &[("u", "UPDATE SC SET QTY = 2 WHERE ID = 2 AND I_ID = 1")], 1.0);
        let m = ConflictMatrix::detect(&rw(&[a, b]));
        assert!(m.ww[0][1].is_false(), "ID=1 vs ID=2 cannot overlap");
    }

    #[test]
    fn range_contradiction_prunes_clause() {
        let a = TxnTemplate::new("a", &[], &[("u", "UPDATE SC SET QTY = 1 WHERE ID < 5 AND I_ID = 1")], 1.0);
        let b = TxnTemplate::new("b", &[], &[("u", "UPDATE SC SET QTY = 2 WHERE ID > 10 AND I_ID = 1")], 1.0);
        let m = ConflictMatrix::detect(&rw(&[a, b]));
        assert!(m.ww[0][1].is_false());
        let c = TxnTemplate::new("c", &[], &[("u", "UPDATE SC SET QTY = 2 WHERE ID >= 3 AND I_ID = 1")], 1.0);
        let a2 = TxnTemplate::new("a", &[], &[("u", "UPDATE SC SET QTY = 1 WHERE ID < 5 AND I_ID = 1")], 1.0);
        let m = ConflictMatrix::detect(&rw(&[a2, c]));
        assert!(!m.ww[0][1].is_false(), "ID in [3,5) overlaps");
    }

    #[test]
    fn param_vs_const_stays_satisfiable() {
        // ID = ?sid vs ID = 7 is satisfiable (sid could be 7).
        let a = TxnTemplate::new(
            "a",
            &["sid"],
            &[("u", "UPDATE SC SET QTY = 1 WHERE ID = ?sid AND I_ID = 0")],
            1.0,
        );
        let b = TxnTemplate::new("b", &[], &[("u", "UPDATE SC SET QTY = 2 WHERE ID = 7 AND I_ID = 0")], 1.0);
        let m = ConflictMatrix::detect(&rw(&[a, b]));
        assert!(!m.ww[0][1].is_false());
        // And it can never be covered (b has no parameters).
        assert!(m.ww[0][1].uncovered(Some("sid"), None));
    }

    #[test]
    fn combined_normalizes_sides() {
        let writer = TxnTemplate::new(
            "w",
            &["wid"],
            &[("u", "UPDATE SC SET QTY = 1 WHERE ID = ?wid")],
            1.0,
        );
        let reader = TxnTemplate::new(
            "r",
            &["rid"],
            &[("q", "SELECT QTY FROM SC WHERE ID = ?rid")],
            1.0,
        );
        let m = ConflictMatrix::detect(&rw(&[writer, reader]));
        // combined(reader, writer) must contain the wr(writer, reader)
        // condition with sides swapped: side0 params named rid.
        let c = m.combined(1, 0);
        assert!(!c.is_false());
        assert!(!c.uncovered(Some("rid"), Some("wid")));
    }

    /// A small concrete world for brute-forcing sided clauses: values for
    /// each of 3 attributes and for each sided parameter name.
    struct World {
        attrs: [i64; 3],
        /// `params[side][p]` — the value of parameter `p` on `side`.
        params: [[i64; 2]; 2],
    }

    const PNAMES: [&str; 2] = ["p", "q"];
    const DOM: i64 = 3; // values range over 0..DOM

    fn atom_holds(a: &SidedAtom, w: &World) -> bool {
        let lhs = w.attrs[a.attr.col];
        let rhs = match &a.rhs {
            SidedRhs::Param { side, name } => {
                let p = PNAMES.iter().position(|n| *n == name.as_str()).unwrap();
                w.params[*side as usize][p]
            }
            SidedRhs::Const(Literal::Int(v)) => *v,
            other => panic!("generator never emits {other:?}"),
        };
        match a.op {
            CmpOp::Eq => lhs == rhs,
            CmpOp::Lt => lhs < rhs,
            other => panic!("generator never emits {other:?}"),
        }
    }

    fn gen_sided_atom(rng: &mut crate::util::Rng) -> SidedAtom {
        SidedAtom {
            attr: AttrId { table: 0, col: rng.range(0, 3) },
            op: if rng.chance(0.8) { CmpOp::Eq } else { CmpOp::Lt },
            rhs: match rng.range(0, 3) {
                0 => SidedRhs::Const(Literal::Int(rng.range(0, DOM as usize) as i64)),
                s => SidedRhs::Param {
                    side: (s - 1) as u8,
                    name: PNAMES[rng.range(0, PNAMES.len())].to_string(),
                },
            },
        }
    }

    /// Enumerate every world over the small domain, calling `f` on each
    /// world that satisfies all atoms of `clause`.
    fn for_each_model(clause: &SClause, mut f: impl FnMut(&World)) {
        let n_worlds = DOM.pow(3 + 4);
        for mut code in 0..n_worlds {
            let mut next = || {
                let v = code % DOM;
                code /= DOM;
                v
            };
            let w = World {
                attrs: [next(), next(), next()],
                params: [[next(), next()], [next(), next()]],
            };
            if clause.0.iter().all(|a| atom_holds(a, &w)) {
                f(&w);
            }
        }
    }

    #[test]
    fn qcheck_covered_clauses_force_equal_routing_values() {
        use crate::util::qcheck::{check, Config};
        // Soundness of the clause-removal rule: if `covered_by(k0, k1)`
        // claims a conflict is made local by routing side 0 on `k0` and
        // side 1 on `k1`, then EVERY concrete world satisfying the clause
        // gives the two routing parameters equal values — the shared
        // deterministic routing function then picks the same server.
        check(Config::default().cases(200).name("sdnf-coverage-soundness"), |rng| {
            let clause = SClause((0..rng.range(1, 6)).map(|_| gen_sided_atom(rng)).collect());
            for k0 in PNAMES {
                for k1 in PNAMES {
                    if !clause.covered_by(k0, k1) {
                        continue;
                    }
                    let p0 = PNAMES.iter().position(|n| *n == k0).unwrap();
                    let p1 = PNAMES.iter().position(|n| *n == k1).unwrap();
                    for_each_model(&clause, |w| {
                        assert_eq!(
                            w.params[0][p0], w.params[1][p1],
                            "covered_by({k0}, {k1}) but a model routes the sides apart: {clause:?}"
                        );
                    });
                }
            }
        });
    }

    #[test]
    fn qcheck_satisfiable_never_prunes_a_clause_with_a_model() {
        use crate::util::qcheck::{check, Config};
        // `satisfiable` is the pruning filter of `pair_condition`: it may
        // keep an unsatisfiable clause (conservative), but it must NEVER
        // report false for a clause that has a concrete model — that
        // would silently drop a real conflict from the matrix.
        check(Config::default().cases(300).name("sdnf-satisfiable-soundness"), |rng| {
            let clause = SClause((0..rng.range(1, 7)).map(|_| gen_sided_atom(rng)).collect());
            let mut has_model = false;
            for_each_model(&clause, |_| has_model = true);
            if has_model {
                assert!(
                    clause.satisfiable(),
                    "clause with a model pruned as unsatisfiable: {clause:?}"
                );
            }
        });
    }

    #[test]
    fn coverage_requires_same_attribute() {
        // t binds SC.ID = a; t' binds SC.I_ID = b — different attributes,
        // equality of routing does not make the conflict local.
        let clause = SClause(vec![
            SidedAtom {
                attr: AttrId { table: 0, col: 0 },
                op: CmpOp::Eq,
                rhs: SidedRhs::Param { side: 0, name: "a".into() },
            },
            SidedAtom {
                attr: AttrId { table: 0, col: 1 },
                op: CmpOp::Eq,
                rhs: SidedRhs::Param { side: 1, name: "b".into() },
            },
        ]);
        assert!(!clause.covered_by("a", "b"));
    }
}
