//! Operation Partitioning — the paper's §3: static extraction of read and
//! write sets, pairwise conflict detection (Algorithm 1), partitioning
//! optimization, and operation classification into commutative / local /
//! global (plus RUBiS-style runtime-conditional local/global).
//!
//! Pipeline:
//!
//! ```text
//! AppSpec ──rwsets──▶ RwSets per txn
//!         ──conflict──▶ ConflictMatrix (per-pair DNF conditions, by kind)
//!         ──elim──▶ EliminationTensor  elim[t,t',k,k']
//!         ──partition──▶ Partitioning  P[t] = param index (cost-minimal)
//!         ──classify──▶ Classification {C, L, G, L/G} + routing spec
//!         ──confluence──▶ promotes mergeable G / L/G to Confluent (CF)
//! ```
//!
//! The candidate scoring inside `partition` can run on the scalar Rust
//! scorer ([`score`]) or on the AOT-compiled JAX/Pallas artifact via
//! [`crate::runtime::CostEvaluator`]; both compute the identical cost.

pub mod classify;
pub mod conflict;
pub mod confluence;
pub mod drift;
pub mod elim;
pub mod hypergraph;
pub mod partition;
pub mod rwsets;
pub mod score;

pub use classify::{classify, Classification, OpClass};
pub use confluence::reclassify;
pub use conflict::{ConflictKind, ConflictMatrix};
pub use drift::{
    assignment_from_wire, assignment_to_wire, pin_classes, AdaptiveConfig, DriftCollector,
    DriftConfig, DriftKind, EpochController,
};
pub use elim::EliminationTensor;
pub use hypergraph::HypergraphScorer;
pub use partition::{optimize, PartitionOptions, Partitioning};
pub use rwsets::{extract_rwsets, AccessEntry, AttrId, Atom, Clause, Dnf, Rhs, RwSets};
