//! Live routing epochs: re-partitioning under workload drift.
//!
//! The paper runs Algorithm 1 once, offline. This module makes the
//! pipeline *live*:
//!
//! ```text
//!  servers count ops per template        (DriftCollector, rides the token)
//!        │
//!        ▼  every `window_rotations` belt rotations, at server 0
//!  EpochController::evaluate(obs, installed)
//!        │   reweight the elimination tensor by observed rates,
//!        │   re-run partition::optimize under the HypergraphScorer,
//!        │   switch iff observed_cost > best_cost × threshold
//!        ▼
//!  new RoutingEpoch { version+1, assignment }   (classes via pin_classes)
//!        │
//!        ▼  version + assignment ride the belt token
//!  every server installs at token receipt  →  total-order barrier
//! ```
//!
//! **Pinned classification.** The static classifier
//! ([`super::classify::classify`]) *grows* routing sets to cover any
//! coverable clause, which makes its final classes independent of the
//! partitioning choice — correct for the offline one-shot, useless for
//! comparing two candidate assignments. Epochs instead pin each template
//! to exactly its chosen parameter ([`pin_classes`]): a template is
//! Local iff *every* conflict it participates in is eliminated under the
//! pinned pair, else Global. This is the §3.2 definition evaluated at a
//! point, and it is exactly what the cost function counts — so the
//! controller's "observed cost" equals the belted traffic fraction the
//! installed epoch actually produces. Pinned epochs never emit
//! `LocalGlobal` (that class *is* the growth the pin removes) or
//! `Confluent` (invariant confluence is workload-static; it neither
//! appears nor disappears with the assignment, and epoch-routed apps
//! keep their static confluent set by construction — see
//! `AnalyzedApp::epoch_from`).
//!
//! **Static vs. adaptive.** "Static routing" in the drift experiments is
//! the same machinery with `threshold = ∞` (epoch 0 pinned forever), so
//! the comparison isolates the re-partitioning decision, not the
//! classifier.

use std::sync::Arc;

use super::classify::{Classification, OpClass};
use super::elim::EliminationTensor;
use super::hypergraph::{template_covered, HypergraphScorer};
use super::partition::{optimize, PartitionOptions};
use super::score::{Assignment, BatchScorer, ScalarScorer};
use crate::workload::analyzed::AnalyzedApp;

/// Knobs for the live-epoch controller.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// Evaluate the controller every this many belt rotations (the
    /// sliding observation window, measured in token laps).
    pub window_rotations: u64,
    /// Switch epochs only when `observed > best × threshold`. Values
    /// close to 1.0 chase noise; `f64::INFINITY` freezes epoch 0
    /// (the "static" arm of the drift experiments).
    pub threshold: f64,
    /// Score candidates with the [`HypergraphScorer`] (per-template
    /// hyperedge cut, weights = observed rates) instead of the scalar
    /// pairwise reference.
    pub hypergraph: bool,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig { window_rotations: 64, threshold: 1.5, hypergraph: true }
    }
}

impl AdaptiveConfig {
    /// The static arm: epochs exist (epoch 0 is pinned) but the
    /// controller never switches.
    pub fn frozen() -> Self {
        AdaptiveConfig { threshold: f64::INFINITY, ..AdaptiveConfig::default() }
    }
}

/// Pin every template to its assigned partitioning parameter and
/// classify at that point: Local iff every conflict the template
/// participates in is eliminated under the pinned pair, Global
/// otherwise, Commutative when it has no conflicts at all.
///
/// Unlike the growth classifier this is *choice-sensitive*: flipping the
/// assignment flips classes, which is the whole point of an epoch.
pub fn pin_classes(tensor: &EliminationTensor, assignment: &Assignment) -> Classification {
    debug_assert_eq!(assignment.len(), tensor.n);
    let n = tensor.n;
    let mut classes = Vec::with_capacity(n);
    for t in 0..n {
        let has_conflict = (0..n).any(|t2| {
            if t <= t2 { tensor.conflict[t][t2] } else { tensor.conflict[t2][t] }
        });
        classes.push(if !has_conflict {
            OpClass::Commutative
        } else if template_covered(tensor, t, assignment) {
            OpClass::Local
        } else {
            OpClass::Global
        });
    }
    Classification {
        classes,
        routing_params: assignment.iter().map(|a| a.iter().copied().collect()).collect(),
        primary: assignment.clone(),
    }
}

/// Per-server sliding-window counter of operations per template. Counts
/// are flushed onto the belt token at each receipt, so the controller at
/// server 0 sees a consistent, totally-ordered global window.
#[derive(Debug, Clone, Default)]
pub struct DriftCollector {
    counts: Vec<u64>,
}

impl DriftCollector {
    pub fn new(n_templates: usize) -> Self {
        DriftCollector { counts: vec![0; n_templates] }
    }

    /// Record one executed (or parked-for-token) operation.
    pub fn note(&mut self, txn: usize) {
        if txn < self.counts.len() {
            self.counts[txn] += 1;
        }
    }

    /// Add the local counts into `sink` (growing it if needed) and reset.
    pub fn flush_into(&mut self, sink: &mut Vec<u64>) {
        if sink.len() < self.counts.len() {
            sink.resize(self.counts.len(), 0);
        }
        for (s, c) in sink.iter_mut().zip(self.counts.iter_mut()) {
            *s += *c;
            *c = 0;
        }
    }

    /// The counts accumulated since the last flush.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }
}

/// The re-partitioning decision procedure. Pure: the same observation
/// window and installed assignment always produce the same decision,
/// which is what lets the decision ride the token without breaking
/// bit-identical determinism.
pub struct EpochController {
    tensor: EliminationTensor,
    cfg: AdaptiveConfig,
}

impl EpochController {
    /// Rebuild the elimination tensor from the analyzed app (the app
    /// discards it after the offline run) and capture the knobs.
    pub fn new(app: &AnalyzedApp, cfg: AdaptiveConfig) -> Self {
        let tensor = EliminationTensor::build(&app.spec.txns, &app.matrix);
        EpochController { tensor, cfg }
    }

    /// Evaluate one observation window against the installed assignment.
    /// Returns the replacement assignment when the observed cost exceeds
    /// the achievable best by the configured threshold, `None` otherwise.
    ///
    /// Both costs come from the *same* scorer over the *same*
    /// rate-reweighted tensor, so the comparison is apples to apples:
    /// with the hypergraph scorer, "cost" is precisely the fraction of
    /// observed traffic the pinned classes would send over the belt.
    pub fn evaluate(&self, obs: &[u64], installed: &Assignment) -> Option<Assignment> {
        let total: u64 = obs.iter().sum();
        if total == 0 || obs.len() != self.tensor.n {
            return None;
        }
        let rates: Vec<f64> = obs.iter().map(|&c| c as f64 / total as f64).collect();
        let mut tensor = self.tensor.clone();
        for t in 0..tensor.n {
            for t2 in t..tensor.n {
                if tensor.conflict[t][t2] {
                    tensor.w2[t][t2] = rates[t] + rates[t2];
                }
            }
        }
        let scorer: Arc<dyn BatchScorer> = if self.cfg.hypergraph {
            Arc::new(HypergraphScorer::new(rates))
        } else {
            Arc::new(ScalarScorer)
        };
        let observed = scorer.score(&tensor, std::slice::from_ref(installed))[0];
        let opts = PartitionOptions { scorer, ..PartitionOptions::default() };
        let best = optimize(&tensor, &opts);
        // NaN-safe by construction: with threshold = ∞ and best.cost = 0
        // the product is NaN and the comparison is false — frozen mode
        // never switches.
        if best.choice != *installed && observed > best.cost * self.cfg.threshold {
            Some(best.choice)
        } else {
            None
        }
    }

    /// The observation window length, in belt rotations.
    pub fn window_rotations(&self) -> u64 {
        self.cfg.window_rotations
    }
}

/// Which deterministic drift scenario a workload generator plays.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DriftKind {
    /// Smooth sinusoidal swing of the hot side with the given period —
    /// "daytime traffic moves from region A's table to region B's".
    Diurnal { period_s: f64 },
    /// Step change at `at_s`: one item suddenly goes viral — traffic
    /// jumps to the hot side *and* concentrates on a single key.
    FlashCrowd { at_s: f64 },
    /// Staircase: every `period_s` the hot key band rotates and the hot
    /// side share steps from `lo` toward `hi`.
    HotKey { period_s: f64 },
}

/// A deterministic drift schedule: a pure function of virtual time, so
/// the generated workload is bit-identical at any thread or
/// client-group count.
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    pub kind: DriftKind,
    /// Share of traffic on the pivot template (the cross-table coupling
    /// op that forces the partitioning trade-off); constant over time.
    pub pivot_share: f64,
    /// B-side share of the remaining traffic before the drift…
    pub lo: f64,
    /// …and after it.
    pub hi: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig {
            kind: DriftKind::FlashCrowd { at_s: 10.0 },
            pivot_share: 0.10,
            lo: 0.2,
            hi: 0.8,
        }
    }
}

impl DriftConfig {
    /// B-side share of non-pivot traffic at virtual time `t_s` seconds.
    pub fn b_share(&self, t_s: f64) -> f64 {
        match self.kind {
            DriftKind::Diurnal { period_s } => {
                let s = 0.5 * (1.0 - (2.0 * std::f64::consts::PI * t_s / period_s).cos());
                self.lo + (self.hi - self.lo) * s
            }
            DriftKind::FlashCrowd { at_s } => {
                if t_s < at_s {
                    self.lo
                } else {
                    self.hi
                }
            }
            DriftKind::HotKey { period_s } => {
                let phase = (t_s / period_s).floor().max(0.0);
                let ramp = (phase / 3.0).min(1.0);
                self.lo + (self.hi - self.lo) * ramp
            }
        }
    }

    /// Key band `[lo, hi)` the B-side draws from at time `t_s`, out of
    /// `keys` total keys. Flash crowds collapse to a single hot item;
    /// hot-key drift rotates a narrow band around the keyspace.
    pub fn key_band(&self, t_s: f64, keys: i64) -> (i64, i64) {
        match self.kind {
            DriftKind::Diurnal { .. } => (0, keys),
            DriftKind::FlashCrowd { at_s } => {
                if t_s < at_s {
                    (0, keys)
                } else {
                    (0, 1)
                }
            }
            DriftKind::HotKey { period_s } => {
                let bw = (keys / 8).max(1);
                let idx = ((t_s / period_s).floor().max(0.0) as i64) % 8;
                (idx * bw, (idx * bw + bw).min(keys))
            }
        }
    }
}

/// Encode an assignment for the token / wire: `-1` marks `None`.
pub fn assignment_to_wire(a: &Assignment) -> Vec<i64> {
    a.iter().map(|x| x.map(|k| k as i64).unwrap_or(-1)).collect()
}

/// Decode a wire assignment (negative = `None`).
pub fn assignment_from_wire(w: &[i64]) -> Assignment {
    w.iter().map(|&v| if v < 0 { None } else { Some(v as usize) }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::conflict::ConflictMatrix;
    use crate::analysis::rwsets::{extract_rwsets, ExtractOptions};
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::workload::spec::{AppSpec, TxnTemplate};

    fn cart_templates() -> (Schema, Vec<TxnTemplate>) {
        let schema = Schema::new(vec![TableSchema::new(
            "SC",
            &[("ID", ValueType::Int), ("I_ID", ValueType::Int), ("QTY", ValueType::Int)],
            &["ID", "I_ID"],
        )]);
        let templates = vec![
            TxnTemplate::new(
                "createCart",
                &["sid"],
                &[("i", "INSERT INTO SC (ID, I_ID, QTY) VALUES (?sid, 0, 0)")],
                1.0,
            ),
            TxnTemplate::new(
                "doCart",
                &["sid", "iid", "q"],
                &[("u", "UPDATE SC SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid")],
                2.0,
            ),
        ];
        (schema, templates)
    }

    fn cart_tensor() -> EliminationTensor {
        let (schema, templates) = cart_templates();
        let rws: Vec<_> = templates
            .iter()
            .map(|t| extract_rwsets(t, &schema, ExtractOptions::default()))
            .collect();
        EliminationTensor::build(&templates, &ConflictMatrix::detect(&rws))
    }

    #[test]
    fn pinning_is_choice_sensitive() {
        let t = cart_tensor();
        // Both on sid: every conflict covered, both Local.
        let good = pin_classes(&t, &vec![Some(0), Some(0)]);
        assert_eq!(good.classes, vec![OpClass::Local, OpClass::Local]);
        assert_eq!(good.routing_params, vec![vec![0], vec![0]]);
        // doCart pinned on iid: the cross pair survives — both Global.
        // (The growth classifier would still call these Local; the pin
        // is what makes epochs comparable by cost.)
        let bad = pin_classes(&t, &vec![Some(0), Some(1)]);
        assert_eq!(bad.classes, vec![OpClass::Global, OpClass::Global]);
        assert_eq!(bad.primary, vec![Some(0), Some(1)]);
    }

    #[test]
    fn pinned_classes_never_grow() {
        let t = cart_tensor();
        for a in [vec![Some(0), Some(0)], vec![Some(0), Some(1)], vec![None, None]] {
            let c = pin_classes(&t, &a);
            assert!(c
                .classes
                .iter()
                .all(|cl| *cl != OpClass::LocalGlobal && *cl != OpClass::Confluent));
        }
    }

    #[test]
    fn collector_flushes_and_resets() {
        let mut col = DriftCollector::new(3);
        col.note(0);
        col.note(2);
        col.note(2);
        let mut sink = Vec::new();
        col.flush_into(&mut sink);
        assert_eq!(sink, vec![1, 0, 2]);
        assert_eq!(col.counts(), &[0, 0, 0]);
        col.note(1);
        col.flush_into(&mut sink);
        assert_eq!(sink, vec![1, 1, 2]);
    }

    fn cart_app() -> AnalyzedApp {
        let (schema, templates) = cart_templates();
        AnalyzedApp::analyze(AppSpec { name: "cart".into(), schema, txns: templates })
    }

    #[test]
    fn controller_switches_away_from_a_broken_epoch() {
        let app = cart_app();
        let ctl = EpochController::new(&app, AdaptiveConfig::default());
        // Installed: doCart pinned on iid — every op pays the belt.
        let installed = vec![Some(0), Some(1)];
        let next = ctl.evaluate(&[100, 200], &installed);
        assert_eq!(next, Some(vec![Some(0), Some(0)]));
        // Already optimal: no switch.
        assert_eq!(ctl.evaluate(&[100, 200], &vec![Some(0), Some(0)]), None);
        // Empty window: no evidence, no switch.
        assert_eq!(ctl.evaluate(&[0, 0], &installed), None);
    }

    #[test]
    fn frozen_controller_never_switches() {
        let app = cart_app();
        let ctl = EpochController::new(&app, AdaptiveConfig::frozen());
        assert_eq!(ctl.evaluate(&[100, 200], &vec![Some(0), Some(1)]), None);
    }

    #[test]
    fn scalar_fallback_agrees_here() {
        let app = cart_app();
        let cfg = AdaptiveConfig { hypergraph: false, ..AdaptiveConfig::default() };
        let ctl = EpochController::new(&app, cfg);
        assert_eq!(
            ctl.evaluate(&[100, 200], &vec![Some(0), Some(1)]),
            Some(vec![Some(0), Some(0)])
        );
    }

    #[test]
    fn drift_schedules_are_pure_and_bounded() {
        let flash = DriftConfig::default();
        assert_eq!(flash.b_share(0.0), 0.2);
        assert_eq!(flash.b_share(9.99), 0.2);
        assert_eq!(flash.b_share(10.0), 0.8);
        assert_eq!(flash.key_band(12.0, 1000), (0, 1));

        let diurnal =
            DriftConfig { kind: DriftKind::Diurnal { period_s: 20.0 }, ..DriftConfig::default() };
        assert!((diurnal.b_share(0.0) - 0.2).abs() < 1e-9);
        assert!((diurnal.b_share(10.0) - 0.8).abs() < 1e-9);
        for i in 0..200 {
            let s = diurnal.b_share(i as f64 * 0.37);
            assert!((0.2..=0.8).contains(&s));
        }

        let hot =
            DriftConfig { kind: DriftKind::HotKey { period_s: 5.0 }, ..DriftConfig::default() };
        assert_eq!(hot.b_share(0.0), 0.2);
        assert_eq!(hot.b_share(16.0), 0.8);
        let (lo, hi) = hot.key_band(7.0, 800);
        assert_eq!(hi - lo, 100);
        assert_ne!(hot.key_band(0.0, 800), hot.key_band(7.0, 800));
    }

    #[test]
    fn wire_roundtrip_preserves_none() {
        let a: Assignment = vec![Some(3), None, Some(0)];
        assert_eq!(assignment_from_wire(&assignment_to_wire(&a)), a);
        assert_eq!(assignment_to_wire(&a), vec![3, -1, 0]);
    }
}
