//! Operation classification (paper §3.2): commutative, local, global —
//! plus the RUBiS-style *local/global* class whose locality is decided at
//! run time from multiple partitioning parameters (paper §3.1, "Multiple
//! partitioning parameters").

use super::conflict::{ConflictMatrix, SDnf};
use super::partition::Partitioning;
use crate::workload::spec::TxnTemplate;

/// Classification of one transaction type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OpClass {
    /// No conflicts with any operation: executable anywhere, immediately.
    Commutative,
    /// Partitioned; executable at its server without coordination.
    Local,
    /// Requires Conveyor Belt coordination (token) before execution.
    Global,
    /// Local iff all routing parameters map to the same server, global
    /// otherwise (the paper's double-key scheme used for RUBiS).
    LocalGlobal,
    /// Invariant-confluent: its remaining conflicts are all provably
    /// mergeable delta compositions w.r.t. the declared schema
    /// invariants (`analysis::confluence`), so it executes immediately
    /// at its home server — bypassing the token queue like
    /// `Commutative` — and its state update replicates as a merged
    /// delta when the token next passes. The engine's bounded-apply
    /// check enforces the invariant locally (abort instead of
    /// coordinate).
    Confluent,
}

/// The classification result for an application.
#[derive(Debug, Clone)]
pub struct Classification {
    pub classes: Vec<OpClass>,
    /// Parameters (indices into each template's param list) consulted by
    /// the deterministic routing function. Empty for commutative
    /// operations (any server may execute them); one entry for plain
    /// local/global/confluent; several for LocalGlobal.
    pub routing_params: Vec<Vec<usize>>,
    /// The optimizer's primary partitioning parameter per transaction
    /// (`Partitioning::choice`), kept so later demotions/promotions
    /// (`force_global`, the confluence pass) can re-anchor
    /// `routing_params` instead of inheriting a stale fixpoint result.
    pub primary: Vec<Option<usize>>,
}

impl Classification {
    pub fn count(&self, class: &OpClass) -> usize {
        self.classes.iter().filter(|c| *c == class).count()
    }

    /// Force a transaction to Global regardless of the computed class.
    ///
    /// This is always *sound* (global is the most conservative class: the
    /// operation executes under the token, totally ordered against every
    /// other global). The paper uses it implicitly for multi-partition
    /// searches — "global operations include a global search for items"
    /// (§6, RUBiS) — which our refined classifier would otherwise keep
    /// local-at-any-replica; forcing them global reproduces the paper's
    /// operation frequencies.
    pub fn force_global(&mut self, txn: usize) {
        self.classes[txn] = OpClass::Global;
        // Globals route by their primary partitioning parameter only;
        // keeping a LocalGlobal's multi-key routing set (or a
        // Commutative's empty one) here would leave the routing table
        // inconsistent with the class.
        self.routing_params[txn] = self.primary[txn].into_iter().collect();
    }

    /// Table 1 row: (local, global, commutative, local/global, confluent).
    pub fn summary(&self) -> (usize, usize, usize, usize, usize) {
        (
            self.count(&OpClass::Local),
            self.count(&OpClass::Global),
            self.count(&OpClass::Commutative),
            self.count(&OpClass::LocalGlobal),
            self.count(&OpClass::Confluent),
        )
    }
}

/// Classify all transactions given the optimized partitioning.
///
/// A transaction `t` is **local** iff (paper §3.2):
/// 1. no write of `t` conflicts with a write of an operation in a
///    different partition (`ww` covered), and
/// 2. no operation in a different partition reads from `t` (`wr[t][·]`
///    covered).
///
/// `t` *reading from* remote operations (`wr[·][t]`) does **not** break
/// locality — that is the add-to-cart / order example of Figure 1.
///
/// Coverage is computed as a fixpoint over *routing sets*: each clause of
/// a locality-breaking condition must be covered by some pair of routing
/// parameters `(k0 ∈ routing(t), k1 ∈ routing(t'))`. Whenever coverage
/// needs a parameter not yet in a routing set, the parameter is added and
/// the fixpoint re-runs — this grows single-key transactions into the
/// double-key (LocalGlobal) scheme exactly when the conflict structure
/// demands it. Clauses no parameter pair can cover make the transaction
/// Global.
pub fn classify(
    templates: &[TxnTemplate],
    matrix: &ConflictMatrix,
    partitioning: &Partitioning,
) -> Classification {
    let n = templates.len();

    // Routing sets start from the optimizer's primary choice.
    let mut routing: Vec<Vec<usize>> =
        (0..n).map(|t| partitioning.choice[t].into_iter().collect()).collect();
    let mut uncoverable = vec![false; n];

    // Locality-breaking conditions of t: (condition with side0 = t, t').
    let conds: Vec<Vec<(&SDnf, usize)>> = (0..n)
        .map(|t| {
            let mut v = Vec::new();
            for t2 in 0..n {
                if !matrix.ww[t][t2].is_false() {
                    v.push((&matrix.ww[t][t2], t2));
                }
                // A reader that declared weak reads does not constrain its
                // writers' locality (paper: global searches observe their
                // server's prefix of the replicated state).
                if !matrix.wr[t][t2].is_false() && !templates[t2].weak_reads {
                    v.push((&matrix.wr[t][t2], t2));
                }
            }
            v
        })
        .collect();

    loop {
        let mut changed = false;
        for t in 0..n {
            for (cond, t2) in &conds[t] {
                for clause in &cond.0 {
                    let covered = routing[t].iter().any(|&k0| {
                        routing[*t2].iter().any(|&k1| {
                            clause.covered_by(&templates[t].params[k0], &templates[*t2].params[k1])
                        })
                    });
                    if covered {
                        continue;
                    }
                    // Search for any covering parameter pair.
                    let pair = (0..templates[t].params.len()).find_map(|k0| {
                        (0..templates[*t2].params.len())
                            .find(|&k1| {
                                clause.covered_by(
                                    &templates[t].params[k0],
                                    &templates[*t2].params[k1],
                                )
                            })
                            .map(|k1| (k0, k1))
                    });
                    match pair {
                        Some((k0, k1)) => {
                            if !routing[t].contains(&k0) {
                                routing[t].push(k0);
                                changed = true;
                            }
                            if !routing[*t2].contains(&k1) {
                                routing[*t2].push(k1);
                                changed = true;
                            }
                        }
                        None => {
                            if !uncoverable[t] {
                                uncoverable[t] = true;
                                changed = true;
                            }
                        }
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }

    let mut classes = Vec::with_capacity(n);
    let mut routing_out = Vec::with_capacity(n);
    for t in 0..n {
        if !matrix.has_any_conflict(t) {
            classes.push(OpClass::Commutative);
            routing_out.push(Vec::new());
            continue;
        }
        if uncoverable[t] {
            classes.push(OpClass::Global);
            // Globals are still partitioned (paper §3.2: they may read
            // from local operations of their partition).
            routing_out.push(partitioning.choice[t].into_iter().collect());
            continue;
        }
        let mut r = routing[t].clone();
        r.sort_unstable();
        if r.len() > 1 {
            classes.push(OpClass::LocalGlobal);
        } else {
            classes.push(OpClass::Local);
        }
        routing_out.push(r);
    }

    Classification { classes, routing_params: routing_out, primary: partitioning.choice.clone() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::elim::EliminationTensor;
    use crate::analysis::partition::{optimize, PartitionOptions};
    use crate::analysis::rwsets::{extract_rwsets, ExtractOptions};
    use crate::catalog::{Schema, TableSchema, ValueType};

    fn run(templates: Vec<TxnTemplate>, schema: Schema) -> Classification {
        let rws: Vec<_> = templates
            .iter()
            .map(|t| extract_rwsets(t, &schema, ExtractOptions::default()))
            .collect();
        let matrix = ConflictMatrix::detect(&rws);
        let tensor = EliminationTensor::build(&templates, &matrix);
        let p = optimize(&tensor, &PartitionOptions::default());
        classify(&templates, &matrix, &p)
    }

    /// The paper's Figure 1 online-store example: create / add / order.
    fn store_schema() -> Schema {
        Schema::new(vec![
            TableSchema::new(
                "CARTS",
                &[("CID", ValueType::Int), ("ITEM", ValueType::Int), ("QTY", ValueType::Int)],
                &["CID", "ITEM"],
            ),
            TableSchema::new(
                "STOCK",
                &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
                &["ITEM"],
            ),
            TableSchema::new(
                "CONFIG",
                &[("K", ValueType::Int), ("V", ValueType::Str)],
                &["K"],
            ),
        ])
    }

    fn store_templates() -> Vec<TxnTemplate> {
        vec![
            // create cart c
            TxnTemplate::new(
                "create",
                &["c"],
                &[("i", "INSERT INTO CARTS (CID, ITEM, QTY) VALUES (?c, 0, 0)")],
                1.0,
            ),
            // add a items of type t to cart c, if stock suffices (reads STOCK.LEVEL)
            TxnTemplate::new(
                "add",
                &["c", "t", "a"],
                &[
                    ("check", "SELECT LEVEL FROM STOCK WHERE ITEM = ?t"),
                    ("upd", "UPDATE CARTS SET QTY = QTY + ?a WHERE CID = ?c AND ITEM = ?t"),
                ],
                1.0,
            ),
            // order cart c: decrement stock of everything in the cart
            TxnTemplate::new(
                "order",
                &["c"],
                &[
                    ("read", "SELECT ITEM, QTY FROM CARTS WHERE CID = ?c"),
                    ("dec", "UPDATE STOCK SET LEVEL = LEVEL - ?q WHERE ITEM = ?derived_item"),
                ],
                1.0,
            ),
            // read immutable configuration
            TxnTemplate::new(
                "config",
                &["k"],
                &[("g", "SELECT V FROM CONFIG WHERE K = ?k")],
                1.0,
            ),
        ]
    }

    #[test]
    fn figure1_classification() {
        let cls = run(store_templates(), store_schema());
        // order: global (WW on STOCK across carts; add reads-from order).
        assert_eq!(cls.classes[2], OpClass::Global, "order must be global");
        // create: local (conflicts only on CARTS keyed by cart id).
        assert_eq!(cls.classes[0], OpClass::Local, "create must be local");
        // add: local — its CARTS writes are cart-keyed; its read of STOCK
        // (reads-from order) does not break locality.
        assert_eq!(cls.classes[1], OpClass::Local, "add must be local");
        // config: commutative (reads immutable CONFIG nobody writes).
        assert_eq!(cls.classes[3], OpClass::Commutative);
    }

    #[test]
    fn read_only_on_written_table_is_not_commutative() {
        // A pure reader of STOCK conflicts (reads-from) with order, so it
        // is not commutative; but nothing reads from it and it writes
        // nothing, so it is local.
        let mut templates = store_templates();
        templates.push(TxnTemplate::new(
            "viewStock",
            &["t"],
            &[("g", "SELECT LEVEL FROM STOCK WHERE ITEM = ?t")],
            1.0,
        ));
        let cls = run(templates, store_schema());
        assert_eq!(cls.classes[4], OpClass::Local);
    }

    #[test]
    fn double_key_yields_local_global() {
        // RUBiS-style: bid(u, i) writes rows keyed by user in USERS and by
        // item in ITEMS; conflicts need u-routing for one and i-routing
        // for the other -> LocalGlobal on {u, i}.
        let schema = Schema::new(vec![
            TableSchema::new(
                "USERS",
                &[("UID", ValueType::Int), ("NBIDS", ValueType::Int)],
                &["UID"],
            ),
            TableSchema::new(
                "ITEMS",
                &[("IID", ValueType::Int), ("MAXBID", ValueType::Int)],
                &["IID"],
            ),
        ]);
        let bid = TxnTemplate::new(
            "bid",
            &["u", "i", "amt"],
            &[
                ("bu", "UPDATE USERS SET NBIDS = NBIDS + 1 WHERE UID = ?u"),
                ("bi", "UPDATE ITEMS SET MAXBID = ?amt WHERE IID = ?i"),
            ],
            1.0,
        );
        let view_user = TxnTemplate::new(
            "viewUser",
            &["u"],
            &[("q", "SELECT NBIDS FROM USERS WHERE UID = ?u")],
            1.0,
        );
        let view_item = TxnTemplate::new(
            "viewItem",
            &["i"],
            &[("q", "SELECT MAXBID FROM ITEMS WHERE IID = ?i")],
            1.0,
        );
        let cls = run(vec![bid, view_user, view_item], schema);
        assert_eq!(cls.classes[0], OpClass::LocalGlobal);
        assert_eq!(cls.routing_params[0].len(), 2);
        assert_eq!(cls.classes[1], OpClass::Local);
        assert_eq!(cls.classes[2], OpClass::Local);
    }

    #[test]
    fn unpartitionable_writer_is_global() {
        // A scan-update with no parameters conflicts with everything on
        // the table and can never be covered.
        let schema = store_schema();
        let mut templates = store_templates();
        templates.push(TxnTemplate::new(
            "restockAll",
            &[],
            &[("u", "UPDATE STOCK SET LEVEL = 100")],
            1.0,
        ));
        let cls = run(templates, schema);
        assert_eq!(cls.classes[4], OpClass::Global);
        // add stays local: its own writes are still cart-keyed, and it
        // only *reads* what restockAll writes.
        assert_eq!(cls.classes[1], OpClass::Local, "add stays local");
        // order is global anyway (WW with restockAll AND with other orders).
        assert_eq!(cls.classes[2], OpClass::Global);
    }

    #[test]
    fn summary_counts() {
        let cls = run(store_templates(), store_schema());
        let (l, g, c, lg, cf) = cls.summary();
        assert_eq!((l, g, c, lg, cf), (2, 1, 1, 0, 0));
    }

    #[test]
    fn force_global_resets_routing_to_primary() {
        // Regression: force_global used to flip the class but leave the
        // transaction's routing_params at the LocalGlobal multi-key set,
        // so routing disagreed with the class it was routing for.
        let schema = Schema::new(vec![
            TableSchema::new(
                "USERS",
                &[("UID", ValueType::Int), ("NBIDS", ValueType::Int)],
                &["UID"],
            ),
            TableSchema::new(
                "ITEMS",
                &[("IID", ValueType::Int), ("MAXBID", ValueType::Int)],
                &["IID"],
            ),
        ]);
        let bid = TxnTemplate::new(
            "bid",
            &["u", "i", "amt"],
            &[
                ("bu", "UPDATE USERS SET NBIDS = NBIDS + 1 WHERE UID = ?u"),
                ("bi", "UPDATE ITEMS SET MAXBID = ?amt WHERE IID = ?i"),
            ],
            1.0,
        );
        let mut cls = run(vec![bid], schema);
        assert_eq!(cls.classes[0], OpClass::LocalGlobal);
        assert_eq!(cls.routing_params[0].len(), 2);

        cls.force_global(0);
        assert_eq!(cls.classes[0], OpClass::Global);
        // Routing collapsed to the optimizer's primary parameter — the
        // same set classify() gives a natural Global.
        assert_eq!(cls.routing_params[0], cls.primary[0].into_iter().collect::<Vec<_>>());
        assert_eq!(cls.routing_params[0].len(), 1);
    }

    #[test]
    fn commutative_write_only_logging() {
        // A write-only table nobody reads: inserts self-conflict on key,
        // but partitioned by the id they become local; if we add a reader
        // they stay local... the paper calls *logging* commutative when
        // its writes are never read. Our conservative analysis still sees
        // insert-insert self WW, so it lands Local (covered by id), which
        // is the sound refinement: it never needs the token.
        let schema = Schema::new(vec![TableSchema::new(
            "LOG",
            &[("ID", ValueType::Int), ("MSG", ValueType::Str)],
            &["ID"],
        )]);
        let log = TxnTemplate::new(
            "log",
            &["id"],
            &[("i", "INSERT INTO LOG (ID, MSG) VALUES (?id, 'x')")],
            1.0,
        );
        let cls = run(vec![log], schema);
        assert_eq!(cls.classes[0], OpClass::Local);
    }
}
