//! Partitioning optimization — the second phase of Algorithm 1.
//!
//! Finds the operation partitioning array `P` (one parameter per
//! transaction) minimizing the weighted volume of surviving global
//! conflicts. The conflict graph is split into connected components;
//! each component is solved independently:
//!
//! * **exhaustively** when the candidate product is small (the common
//!   case the paper reports: "an exhaustive search of all possible
//!   partitionings is feasible"), with candidates scored in batches
//!   through a pluggable [`BatchScorer`] (scalar, or the AOT Pallas
//!   artifact via PJRT);
//! * by **greedy coordinate descent with restarts** otherwise (the
//!   paper's "more sophisticated search strategies" escape hatch).

use super::elim::EliminationTensor;
use super::score::{Assignment, BatchScorer, ScalarScorer};
use crate::util::Rng;
use std::sync::Arc;

#[derive(Clone)]
pub struct PartitionOptions {
    /// Max candidates per component for the exhaustive path.
    pub exhaustive_limit: u64,
    /// Candidate batch size fed to the scorer.
    pub batch: usize,
    /// Scorer implementation (defaults to the scalar reference).
    pub scorer: Arc<dyn BatchScorer>,
    /// Restarts for the greedy fallback.
    pub restarts: usize,
    pub seed: u64,
}

impl Default for PartitionOptions {
    fn default() -> Self {
        PartitionOptions {
            exhaustive_limit: 2_000_000,
            batch: 256,
            scorer: Arc::new(ScalarScorer),
            restarts: 16,
            seed: 0xE11A,
        }
    }
}

impl std::fmt::Debug for PartitionOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PartitionOptions")
            .field("exhaustive_limit", &self.exhaustive_limit)
            .field("batch", &self.batch)
            .field("scorer", &self.scorer.name())
            .finish()
    }
}

/// The result of partitioning optimization.
#[derive(Debug, Clone)]
pub struct Partitioning {
    /// Per transaction: chosen partitioning parameter index.
    pub choice: Assignment,
    /// Cost of the final assignment (Algorithm 1 line 20).
    pub cost: f64,
    /// Whether any component fell back to greedy search.
    pub exact: bool,
}

/// Optimize the partitioning array for `tensor`.
pub fn optimize(tensor: &EliminationTensor, opts: &PartitionOptions) -> Partitioning {
    let mut assign: Assignment = tensor
        .kdims
        .iter()
        .map(|&k| if k > 0 { Some(0) } else { None })
        .collect();
    let mut exact = true;

    for comp in tensor.components() {
        // Only transactions with parameters are search variables.
        let vars: Vec<usize> = comp.iter().copied().filter(|&t| tensor.kdims[t] > 0).collect();
        if vars.is_empty() {
            continue;
        }
        let space: u64 = vars
            .iter()
            .map(|&t| tensor.kdims[t] as u64)
            .try_fold(1u64, |acc, k| acc.checked_mul(k))
            .unwrap_or(u64::MAX);
        if space <= opts.exhaustive_limit {
            exhaustive(tensor, &vars, &mut assign, opts);
        } else {
            greedy(tensor, &vars, &mut assign, opts);
            exact = false;
        }
    }

    let final_cost = opts.scorer.score(tensor, std::slice::from_ref(&assign))[0];
    Partitioning { choice: assign, cost: final_cost, exact }
}

/// Enumerate every assignment of `vars` (mixed radix), scoring in batches.
fn exhaustive(
    tensor: &EliminationTensor,
    vars: &[usize],
    assign: &mut Assignment,
    opts: &PartitionOptions,
) {
    let radix: Vec<usize> = vars.iter().map(|&t| tensor.kdims[t]).collect();
    let mut counter = vec![0usize; vars.len()];
    let mut done = false;

    let mut best_cost = f64::INFINITY;
    let mut best: Vec<usize> = counter.clone();

    let mut batch: Vec<Assignment> = Vec::with_capacity(opts.batch);
    let mut batch_counters: Vec<Vec<usize>> = Vec::with_capacity(opts.batch);

    while !done {
        let mut candidate = assign.clone();
        for (i, &t) in vars.iter().enumerate() {
            candidate[t] = Some(counter[i]);
        }
        batch.push(candidate);
        batch_counters.push(counter.clone());

        // Advance mixed-radix counter.
        let mut i = 0;
        loop {
            if i == vars.len() {
                done = true;
                break;
            }
            counter[i] += 1;
            if counter[i] < radix[i] {
                break;
            }
            counter[i] = 0;
            i += 1;
        }

        if batch.len() == opts.batch || done {
            let scores = opts.scorer.score(tensor, &batch);
            for (s, c) in scores.iter().zip(&batch_counters) {
                if *s < best_cost {
                    best_cost = *s;
                    best = c.clone();
                }
            }
            batch.clear();
            batch_counters.clear();
        }
    }

    for (i, &t) in vars.iter().enumerate() {
        assign[t] = Some(best[i]);
    }
}

/// Greedy coordinate descent with random restarts.
fn greedy(
    tensor: &EliminationTensor,
    vars: &[usize],
    assign: &mut Assignment,
    opts: &PartitionOptions,
) {
    let mut rng = Rng::new(opts.seed);
    let mut best_assign = assign.clone();
    let mut best_cost = f64::INFINITY;

    for _ in 0..opts.restarts.max(1) {
        let mut cur = assign.clone();
        for &t in vars {
            cur[t] = Some(rng.range(0, tensor.kdims[t]));
        }
        let mut cur_cost = opts.scorer.score(tensor, std::slice::from_ref(&cur))[0];
        loop {
            let mut improved = false;
            for &t in vars {
                let orig = cur[t];
                for k in 0..tensor.kdims[t] {
                    if Some(k) == orig {
                        continue;
                    }
                    cur[t] = Some(k);
                    let c = opts.scorer.score(tensor, std::slice::from_ref(&cur))[0];
                    if c < cur_cost {
                        cur_cost = c;
                        improved = true;
                    } else {
                        cur[t] = orig;
                    }
                }
            }
            if !improved {
                break;
            }
        }
        if cur_cost < best_cost {
            best_cost = cur_cost;
            best_assign = cur;
        }
    }
    *assign = best_assign;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::conflict::ConflictMatrix;
    use crate::analysis::rwsets::{extract_rwsets, ExtractOptions};
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::workload::spec::TxnTemplate;

    fn build(templates: &[TxnTemplate], schema: &Schema) -> EliminationTensor {
        let rws: Vec<_> = templates
            .iter()
            .map(|t| extract_rwsets(t, schema, ExtractOptions::default()))
            .collect();
        EliminationTensor::build(templates, &ConflictMatrix::detect(&rws))
    }

    fn cart_schema() -> Schema {
        Schema::new(vec![TableSchema::new(
            "SC",
            &[("ID", ValueType::Int), ("I_ID", ValueType::Int), ("QTY", ValueType::Int)],
            &["ID", "I_ID"],
        )])
    }

    #[test]
    fn finds_the_paper_partitioning() {
        // createCart(sid) + doCart(sid, iid, q): the optimum partitions
        // both on sid with zero residual cost.
        let templates = vec![
            TxnTemplate::new(
                "createCart",
                &["sid"],
                &[("i", "INSERT INTO SC (ID, I_ID, QTY) VALUES (?sid, 0, 0)")],
                1.0,
            ),
            TxnTemplate::new(
                "doCart",
                &["iid", "sid", "q"], // sid deliberately NOT first
                &[("u", "UPDATE SC SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid")],
                2.0,
            ),
        ];
        let tensor = build(&templates, &cart_schema());
        let p = optimize(&tensor, &PartitionOptions::default());
        assert!(p.exact);
        assert_eq!(p.cost, 0.0);
        assert_eq!(p.choice[0], Some(0)); // createCart -> sid
        assert_eq!(p.choice[1], Some(1)); // doCart -> sid (index 1)
    }

    #[test]
    fn weights_steer_the_choice() {
        // A txn conflicting with two others on different parameters: the
        // optimizer must side with the heavier partner.
        let schema = Schema::new(vec![TableSchema::new(
            "T",
            &[("A", ValueType::Int), ("B", ValueType::Int), ("V", ValueType::Int)],
            &["A", "B"],
        )]);
        let mid = TxnTemplate::new(
            "mid",
            &["a", "b"],
            &[("u", "UPDATE T SET V = 1 WHERE A = ?a AND B = ?b")],
            1.0,
        );
        let heavy = TxnTemplate::new(
            "heavy",
            &["a"],
            &[("u", "UPDATE T SET V = 2 WHERE A = ?a")],
            10.0,
        );
        let light = TxnTemplate::new(
            "light",
            &["b"],
            &[("u", "UPDATE T SET V = 3 WHERE B = ?b")],
            0.1,
        );
        let tensor = build(&[mid, heavy, light], &schema);
        let p = optimize(&tensor, &PartitionOptions::default());
        // mid must partition on `a` to localize the conflict with heavy.
        assert_eq!(p.choice[0], Some(0), "cost={}", p.cost);
    }

    #[test]
    fn greedy_fallback_reaches_exhaustive_quality_on_small_instance() {
        let templates = vec![
            TxnTemplate::new(
                "createCart",
                &["sid"],
                &[("i", "INSERT INTO SC (ID, I_ID, QTY) VALUES (?sid, 0, 0)")],
                1.0,
            ),
            TxnTemplate::new(
                "doCart",
                &["iid", "sid", "q"],
                &[("u", "UPDATE SC SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid")],
                2.0,
            ),
        ];
        let tensor = build(&templates, &cart_schema());
        let exact = optimize(&tensor, &PartitionOptions::default());
        let forced_greedy = optimize(
            &tensor,
            &PartitionOptions { exhaustive_limit: 0, ..Default::default() },
        );
        assert!(!forced_greedy.exact);
        assert_eq!(forced_greedy.cost, exact.cost);
    }

    #[test]
    fn batch_boundaries_do_not_change_result() {
        let templates = vec![
            TxnTemplate::new(
                "a",
                &["x", "y"],
                &[("u", "UPDATE SC SET QTY = 1 WHERE ID = ?x AND I_ID = ?y")],
                1.0,
            ),
            TxnTemplate::new(
                "b",
                &["x", "y"],
                &[("u", "UPDATE SC SET QTY = 2 WHERE ID = ?x AND I_ID = ?y")],
                1.0,
            ),
        ];
        let tensor = build(&templates, &cart_schema());
        let p1 = optimize(&tensor, &PartitionOptions { batch: 1, ..Default::default() });
        let p3 = optimize(&tensor, &PartitionOptions { batch: 3, ..Default::default() });
        let p256 = optimize(&tensor, &PartitionOptions::default());
        assert_eq!(p1.cost, p256.cost);
        assert_eq!(p3.cost, p256.cost);
        assert_eq!(p1.choice, p256.choice);
    }

    #[test]
    fn property_optimizer_never_beats_brute_force() {
        // qcheck: on random small tensors, optimize() cost equals the
        // true minimum found by independent brute force.
        crate::util::qcheck::check(
            crate::util::qcheck::Config::default().cases(25).name("optimize=bruteforce"),
            |rng| {
                let nt = rng.range(1, 4);
                let schema = cart_schema();
                let params = ["p0", "p1", "p2"];
                let templates: Vec<TxnTemplate> = (0..nt)
                    .map(|i| {
                        let np = rng.range(1, 3);
                        let use_p: Vec<&str> = params[..np].to_vec();
                        // Random equality structure on ID / I_ID.
                        let cond = match rng.range(0, 3) {
                            0 => format!("ID = ?{}", use_p[0]),
                            1 => format!("I_ID = ?{}", use_p[np - 1]),
                            _ => format!("ID = ?{} AND I_ID = ?{}", use_p[0], use_p[np - 1]),
                        };
                        TxnTemplate::new(
                            Box::leak(format!("t{i}").into_boxed_str()),
                            &use_p,
                            &[("u", Box::leak(format!("UPDATE SC SET QTY = 1 WHERE {cond}").into_boxed_str()))],
                            1.0 + rng.range(0, 5) as f64,
                        )
                    })
                    .collect();
                let tensor = build(&templates, &schema);
                let opt = optimize(&tensor, &PartitionOptions::default());
                // Brute force.
                let mut best = f64::INFINITY;
                let radix: Vec<usize> = tensor.kdims.clone();
                let total: usize = radix.iter().map(|&k| k.max(1)).product();
                for mut idx in 0..total {
                    let mut assign = Vec::new();
                    for &k in &radix {
                        if k == 0 {
                            assign.push(None);
                        } else {
                            assign.push(Some(idx % k));
                            idx /= k;
                        }
                    }
                    best = best.min(crate::analysis::score::cost(&tensor, &assign));
                }
                assert_eq!(opt.cost, best);
            },
        );
    }
}
