//! Read/write-set extraction from transaction templates (paper §3.1).
//!
//! Each SQL statement of a template yields one entry `e = ⟨A, C⟩`:
//! `A` = accessed attributes, `C` = the selection condition, normalized
//! to disjunctive normal form. Extraction is *pessimistic*: every
//! statement of the template is included regardless of execution path.

use crate::catalog::Schema;
use crate::sqlir::{CmpOp, Literal, Pred, Scalar, SelectItem, Stmt};
use crate::workload::spec::TxnTemplate;

/// A table attribute `(table id, column id)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AttrId {
    pub table: usize,
    pub col: usize,
}

/// The right-hand side of an atomic condition, as the analysis sees it.
#[derive(Debug, Clone, PartialEq)]
pub enum Rhs {
    /// A transaction *input* parameter (candidate partitioning parameter).
    Param(String),
    /// A compile-time constant.
    Const(Literal),
    /// Anything the analysis cannot reason about: derived values bound at
    /// run time, column references, arithmetic. Conservatively treated as
    /// "could be any value".
    Opaque,
}

/// An atomic condition `attr op rhs`.
#[derive(Debug, Clone, PartialEq)]
pub struct Atom {
    pub attr: AttrId,
    pub op: CmpOp,
    pub rhs: Rhs,
}

/// A conjunction of atoms. An empty clause is `true`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Clause(pub Vec<Atom>);

/// A disjunction of clauses. An empty DNF is `false`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Dnf(pub Vec<Clause>);

impl Dnf {
    pub fn false_() -> Dnf {
        Dnf(Vec::new())
    }

    pub fn true_() -> Dnf {
        Dnf(vec![Clause::default()])
    }

    pub fn is_false(&self) -> bool {
        self.0.is_empty()
    }

    /// Distribute a conjunction of two DNFs.
    pub fn and(&self, other: &Dnf) -> Dnf {
        let mut out = Vec::with_capacity(self.0.len() * other.0.len());
        for a in &self.0 {
            for b in &other.0 {
                let mut atoms = a.0.clone();
                atoms.extend(b.0.iter().cloned());
                out.push(Clause(atoms));
            }
        }
        Dnf(out)
    }

    pub fn or(&self, other: &Dnf) -> Dnf {
        let mut out = self.0.clone();
        out.extend(other.0.iter().cloned());
        Dnf(out)
    }
}

/// One read- or write-set entry `⟨A, C⟩`.
#[derive(Debug, Clone)]
pub struct AccessEntry {
    pub attrs: Vec<AttrId>,
    pub cond: Dnf,
    /// Statement name (diagnostics).
    pub stmt: String,
}

/// The read and write sets of one transaction template.
#[derive(Debug, Clone, Default)]
pub struct RwSets {
    pub reads: Vec<AccessEntry>,
    pub writes: Vec<AccessEntry>,
}

/// Extraction options.
#[derive(Debug, Clone, Copy)]
pub struct ExtractOptions {
    /// Paper-faithful mode (`false`): SELECT read attributes are the
    /// *projected* columns only ("read and returned as output", §3.1).
    /// Strict mode (`true`) additionally includes WHERE columns of
    /// SELECTs and columns read by UPDATE SET arithmetic — a sound
    /// over-approximation used by the ablation bench.
    pub strict_reads: bool,
}

impl Default for ExtractOptions {
    fn default() -> Self {
        ExtractOptions { strict_reads: false }
    }
}

/// Classify a scalar RHS given the template's input parameters.
fn rhs_of(scalar: &Scalar, input_params: &[String]) -> Rhs {
    match scalar {
        Scalar::Lit(l) => Rhs::Const(l.clone()),
        Scalar::Param(p) => {
            if input_params.iter().any(|ip| ip == p) {
                Rhs::Param(p.clone())
            } else {
                Rhs::Opaque
            }
        }
        _ => Rhs::Opaque,
    }
}

/// Normalize a WHERE predicate to DNF over analysis atoms.
fn pred_to_dnf(pred: &Pred, table: usize, schema: &Schema, input_params: &[String]) -> Dnf {
    match pred {
        Pred::True => Dnf::true_(),
        Pred::Cmp { col, op, rhs } => {
            let ts = schema.table(table);
            match ts.col_index(col) {
                Some(ci) => {
                    let atom = Atom {
                        attr: AttrId { table, col: ci },
                        op: *op,
                        rhs: rhs_of(rhs, input_params),
                    };
                    Dnf(vec![Clause(vec![atom])])
                }
                // Unknown column: treat the atom as unconstrained (true).
                None => Dnf::true_(),
            }
        }
        Pred::And(ps) => {
            let mut acc = Dnf::true_();
            for p in ps {
                acc = acc.and(&pred_to_dnf(p, table, schema, input_params));
            }
            acc
        }
        Pred::Or(ps) => {
            let mut acc = Dnf::false_();
            for p in ps {
                acc = acc.or(&pred_to_dnf(p, table, schema, input_params));
            }
            acc
        }
    }
}

/// Extract the read and write sets of a template (paper §3.1).
pub fn extract_rwsets(tpl: &TxnTemplate, schema: &Schema, opts: ExtractOptions) -> RwSets {
    let mut rw = RwSets::default();
    for (sname, stmt) in &tpl.stmts {
        let table = match schema.table_id(stmt.table()) {
            Some(t) => t,
            None => panic!("template {}: unknown table {}", tpl.name, stmt.table()),
        };
        let ts = schema.table(table);
        match stmt {
            Stmt::Select(s) => {
                let mut attrs: Vec<AttrId> = if s.items.is_empty() {
                    (0..ts.ncols()).map(|col| AttrId { table, col }).collect()
                } else {
                    s.items
                        .iter()
                        .filter_map(|i| match i {
                            SelectItem::Count => None,
                            other => other
                                .referenced_col()
                                .and_then(|c| ts.col_index(c))
                                .map(|col| AttrId { table, col }),
                        })
                        .collect()
                };
                // COUNT(*) reads row existence: model it as reading the PK.
                if s.items.iter().any(|i| matches!(i, SelectItem::Count)) {
                    for pkc in ts.pk_indices() {
                        attrs.push(AttrId { table, col: pkc });
                    }
                }
                if opts.strict_reads {
                    let mut cols = Vec::new();
                    s.where_.referenced_cols(&mut cols);
                    for c in cols {
                        if let Some(col) = ts.col_index(c) {
                            attrs.push(AttrId { table, col });
                        }
                    }
                }
                attrs.sort_unstable();
                attrs.dedup();
                let cond = pred_to_dnf(&s.where_, table, schema, &tpl.params);
                rw.reads.push(AccessEntry { attrs, cond, stmt: sname.clone() });
            }
            Stmt::Insert(ins) => {
                // Write attributes: every column of the new row (also the
                // implicit NULLs — the row springs into existence).
                let attrs: Vec<AttrId> =
                    (0..ts.ncols()).map(|col| AttrId { table, col }).collect();
                // Condition: col = value for each explicitly inserted column
                // (the paper's createCart example: SC.ID = sid).
                let mut atoms = Vec::new();
                for (c, v) in ins.columns.iter().zip(&ins.values) {
                    if let Some(ci) = ts.col_index(c) {
                        atoms.push(Atom {
                            attr: AttrId { table, col: ci },
                            op: CmpOp::Eq,
                            rhs: rhs_of(v, &tpl.params),
                        });
                    }
                }
                rw.writes.push(AccessEntry {
                    attrs,
                    cond: Dnf(vec![Clause(atoms)]),
                    stmt: sname.clone(),
                });
            }
            Stmt::Update(u) => {
                let mut attrs: Vec<AttrId> = u
                    .sets
                    .iter()
                    .filter_map(|(c, _)| ts.col_index(c).map(|col| AttrId { table, col }))
                    .collect();
                attrs.sort_unstable();
                attrs.dedup();
                let cond = pred_to_dnf(&u.where_, table, schema, &tpl.params);
                rw.writes.push(AccessEntry { attrs, cond: cond.clone(), stmt: sname.clone() });
                if opts.strict_reads {
                    // The UPDATE reads its WHERE columns and any columns in
                    // SET arithmetic (e.g. STOCK = STOCK - ?q reads STOCK).
                    let mut cols = Vec::new();
                    u.where_.referenced_cols(&mut cols);
                    for (_, v) in &u.sets {
                        v.referenced_cols(&mut cols);
                    }
                    let mut rattrs: Vec<AttrId> = cols
                        .into_iter()
                        .filter_map(|c| ts.col_index(c).map(|col| AttrId { table, col }))
                        .collect();
                    rattrs.sort_unstable();
                    rattrs.dedup();
                    if !rattrs.is_empty() {
                        rw.reads.push(AccessEntry { attrs: rattrs, cond, stmt: sname.clone() });
                    }
                }
            }
            Stmt::Delete(d) => {
                // A delete writes (removes) every attribute of the rows.
                let attrs: Vec<AttrId> =
                    (0..ts.ncols()).map(|col| AttrId { table, col }).collect();
                let cond = pred_to_dnf(&d.where_, table, schema, &tpl.params);
                rw.writes.push(AccessEntry { attrs, cond, stmt: sname.clone() });
            }
        }
    }
    rw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Schema, TableSchema, ValueType};

    fn schema() -> Schema {
        Schema::new(vec![TableSchema::new(
            "SC",
            &[("ID", ValueType::Int), ("I_ID", ValueType::Int), ("QTY", ValueType::Int)],
            &["ID", "I_ID"],
        )])
    }

    #[test]
    fn paper_docart_write_set() {
        // The paper's running example: doCart's UPDATE yields write entry
        // ⟨{SC.QTY}, SC.ID = sid ∧ SC.I_ID = iid⟩.
        let tpl = TxnTemplate::new(
            "doCart",
            &["sid", "iid", "q"],
            &[("upd", "UPDATE SC SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid")],
            1.0,
        );
        let rw = extract_rwsets(&tpl, &schema(), ExtractOptions::default());
        assert_eq!(rw.reads.len(), 0);
        assert_eq!(rw.writes.len(), 1);
        let w = &rw.writes[0];
        assert_eq!(w.attrs, vec![AttrId { table: 0, col: 2 }]); // QTY
        assert_eq!(w.cond.0.len(), 1);
        let clause = &w.cond.0[0];
        assert_eq!(clause.0.len(), 2);
        assert!(clause.0.iter().any(|a| a.attr.col == 0 && a.rhs == Rhs::Param("sid".into())));
        assert!(clause.0.iter().any(|a| a.attr.col == 1 && a.rhs == Rhs::Param("iid".into())));
    }

    #[test]
    fn paper_createcart_insert_condition() {
        let tpl = TxnTemplate::new(
            "createCart",
            &["sid"],
            &[("ins", "INSERT INTO SC (ID, I_ID, QTY) VALUES (?sid, 0, 0)")],
            1.0,
        );
        let rw = extract_rwsets(&tpl, &schema(), ExtractOptions::default());
        let w = &rw.writes[0];
        // Insert writes all columns.
        assert_eq!(w.attrs.len(), 3);
        let clause = &w.cond.0[0];
        // Condition: ID = sid AND I_ID = 0 AND QTY = 0.
        assert!(clause.0.iter().any(|a| a.attr.col == 0 && a.rhs == Rhs::Param("sid".into())));
        assert!(clause
            .0
            .iter()
            .any(|a| a.attr.col == 1 && a.rhs == Rhs::Const(Literal::Int(0))));
    }

    #[test]
    fn select_reads_projection_only_unless_strict() {
        let tpl = TxnTemplate::new(
            "getQty",
            &["sid"],
            &[("q", "SELECT QTY FROM SC WHERE ID = ?sid")],
            1.0,
        );
        let rw = extract_rwsets(&tpl, &schema(), ExtractOptions::default());
        assert_eq!(rw.reads[0].attrs, vec![AttrId { table: 0, col: 2 }]);
        let rw = extract_rwsets(&tpl, &schema(), ExtractOptions { strict_reads: true });
        // Strict mode adds the WHERE column ID.
        assert_eq!(
            rw.reads[0].attrs,
            vec![AttrId { table: 0, col: 0 }, AttrId { table: 0, col: 2 }]
        );
    }

    #[test]
    fn derived_params_are_opaque() {
        // `?derived` is not an input parameter of the template.
        let tpl = TxnTemplate::new(
            "useDerived",
            &["sid"],
            &[("q", "SELECT QTY FROM SC WHERE ID = ?derived")],
            1.0,
        );
        let rw = extract_rwsets(&tpl, &schema(), ExtractOptions::default());
        let atom = &rw.reads[0].cond.0[0].0[0];
        assert_eq!(atom.rhs, Rhs::Opaque);
    }

    #[test]
    fn or_where_produces_two_clauses() {
        let tpl = TxnTemplate::new(
            "either",
            &["a", "b"],
            &[("q", "SELECT QTY FROM SC WHERE ID = ?a OR ID = ?b")],
            1.0,
        );
        let rw = extract_rwsets(&tpl, &schema(), ExtractOptions::default());
        assert_eq!(rw.reads[0].cond.0.len(), 2);
    }

    #[test]
    fn select_star_reads_all_columns() {
        let tpl =
            TxnTemplate::new("all", &["sid"], &[("q", "SELECT * FROM SC WHERE ID = ?sid")], 1.0);
        let rw = extract_rwsets(&tpl, &schema(), ExtractOptions::default());
        assert_eq!(rw.reads[0].attrs.len(), 3);
    }

    #[test]
    fn delete_writes_all_columns() {
        let tpl = TxnTemplate::new(
            "rm",
            &["sid"],
            &[("d", "DELETE FROM SC WHERE ID = ?sid")],
            1.0,
        );
        let rw = extract_rwsets(&tpl, &schema(), ExtractOptions::default());
        assert_eq!(rw.writes[0].attrs.len(), 3);
    }

    #[test]
    fn dnf_and_distributes() {
        let a = Dnf(vec![Clause(vec![]), Clause(vec![])]); // true OR true
        let b = Dnf(vec![Clause(vec![]), Clause(vec![]), Clause(vec![])]);
        assert_eq!(a.and(&b).0.len(), 6);
        assert!(Dnf::false_().and(&b).is_false());
    }

    /// Random boolean formula over a few abstract propositions.
    enum Form {
        Leaf(usize),
        And(Box<Form>, Box<Form>),
        Or(Box<Form>, Box<Form>),
    }

    const NPROPS: usize = 4;

    fn gen_form(rng: &mut crate::util::Rng, depth: usize) -> Form {
        if depth == 0 || rng.chance(0.35) {
            Form::Leaf(rng.range(0, NPROPS))
        } else if rng.chance(0.5) {
            Form::And(Box::new(gen_form(rng, depth - 1)), Box::new(gen_form(rng, depth - 1)))
        } else {
            Form::Or(Box::new(gen_form(rng, depth - 1)), Box::new(gen_form(rng, depth - 1)))
        }
    }

    fn eval_form(f: &Form, env: u32) -> bool {
        match f {
            Form::Leaf(i) => (env >> i) & 1 == 1,
            Form::And(a, b) => eval_form(a, env) && eval_form(b, env),
            Form::Or(a, b) => eval_form(a, env) || eval_form(b, env),
        }
    }

    /// Proposition `i` encoded as an analysis atom (which proposition it
    /// is lives in the column id; op/rhs are irrelevant to the algebra).
    fn prop_atom(i: usize) -> Atom {
        Atom {
            attr: AttrId { table: 0, col: i },
            op: CmpOp::Eq,
            rhs: Rhs::Const(Literal::Int(1)),
        }
    }

    fn form_to_dnf(f: &Form) -> Dnf {
        match f {
            Form::Leaf(i) => Dnf(vec![Clause(vec![prop_atom(*i)])]),
            Form::And(a, b) => form_to_dnf(a).and(&form_to_dnf(b)),
            Form::Or(a, b) => form_to_dnf(a).or(&form_to_dnf(b)),
        }
    }

    fn eval_dnf(d: &Dnf, env: u32) -> bool {
        d.0.iter().any(|c| c.0.iter().all(|a| (env >> a.attr.col) & 1 == 1))
    }

    #[test]
    fn qcheck_dnf_algebra_matches_truth_table() {
        use crate::util::qcheck::{check, Config};
        // `and`/`or` must preserve the boolean function of the formula:
        // the DNF normalization of a random formula agrees with direct
        // evaluation on every assignment of the propositions.
        check(Config::default().cases(300).name("dnf-truth-table"), |rng| {
            let f = gen_form(rng, 4);
            let d = form_to_dnf(&f);
            for env in 0..(1u32 << NPROPS) {
                assert_eq!(
                    eval_dnf(&d, env),
                    eval_form(&f, env),
                    "DNF disagrees with formula at env {env:#06b}"
                );
            }
            // Lattice identities: false is absorbing for AND, neutral
            // for OR; true is neutral for AND.
            assert!(Dnf::false_().and(&d).is_false());
            for env in 0..(1u32 << NPROPS) {
                assert_eq!(eval_dnf(&Dnf::true_().and(&d), env), eval_dnf(&d, env));
                assert_eq!(eval_dnf(&Dnf::false_().or(&d), env), eval_dnf(&d, env));
            }
        });
    }
}
