//! Experiment harness: client ramps, peak-throughput search under a
//! latency SLA (the paper's "peak throughput is the maximum throughput a
//! system can sustain while ensuring an average latency of less than
//! 2000 ms"), and table/figure report rendering.

pub mod experiments;
pub mod report;

use crate::simnet::metrics::SimMetrics;
use crate::util::stats::Summary;

/// One measured load point of a throughput/latency curve.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    pub clients: usize,
    pub throughput: f64,
    pub mean_latency_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub completed: u64,
}

impl LoadPoint {
    pub fn from_summary(clients: usize, throughput: f64, lat: &mut Summary, completed: u64) -> Self {
        LoadPoint {
            clients,
            throughput,
            mean_latency_ms: lat.mean(),
            p50_ms: lat.p50(),
            p99_ms: lat.p99(),
            completed,
        }
    }

    /// Build a point from the mergeable bucketed histograms. Unlike
    /// [`LoadPoint::from_summary`], this is defined in both metric
    /// modes — the exact per-sample `Summary`s are skipped entirely at
    /// [`crate::simnet::ClientsConfig::bucketed`] scale — at the
    /// histogram's ~3% quantile resolution.
    pub fn from_metrics(clients: usize, throughput: f64, m: &SimMetrics) -> Self {
        LoadPoint {
            clients,
            throughput,
            mean_latency_ms: m.latency_hist.mean_ms(),
            p50_ms: m.latency_hist.p50_ms(),
            p99_ms: m.latency_hist.p99_ms(),
            completed: m.completed,
        }
    }
}

/// The result of a [`Curve::peak`] search: the selected point plus
/// whether it actually met the SLA. `met_sla == false` means the curve
/// never got under the SLA and `point` is merely its least-bad
/// (lowest-latency) point — report it as overload, not as a peak.
#[derive(Debug, Clone, Copy)]
pub struct Peak<'a> {
    /// The selected load point.
    pub point: &'a LoadPoint,
    /// True when `point` satisfies the SLA; false for the all-points-
    /// violate fallback.
    pub met_sla: bool,
}

/// A measured throughput/latency curve for one system configuration.
#[derive(Debug, Clone, Default)]
pub struct Curve {
    pub label: String,
    pub points: Vec<LoadPoint>,
}

impl Curve {
    pub fn new(label: impl Into<String>) -> Self {
        Curve { label: label.into(), points: Vec::new() }
    }

    /// Peak throughput under the SLA: max throughput among points whose
    /// mean latency stays below `sla_ms`. When *every* point violates
    /// the SLA, falls back to the lowest-latency point but says so via
    /// [`Peak::met_sla`] — callers used to render that fallback as a
    /// legitimate "peak throughput", silently reporting an overloaded
    /// system as healthy.
    pub fn peak(&self, sla_ms: f64) -> Option<Peak<'_>> {
        let ok = self
            .points
            .iter()
            .filter(|p| p.mean_latency_ms < sla_ms)
            .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap());
        match ok {
            Some(point) => Some(Peak { point, met_sla: true }),
            None => self
                .points
                .iter()
                .min_by(|a, b| a.mean_latency_ms.partial_cmp(&b.mean_latency_ms).unwrap())
                .map(|point| Peak { point, met_sla: false }),
        }
    }

    /// Latency at the lightest measured load.
    pub fn light_load_latency(&self) -> Option<f64> {
        self.points
            .iter()
            .min_by_key(|p| p.clients)
            .map(|p| p.mean_latency_ms)
    }
}

/// Ramp a system over a client ladder: `run(clients)` measures one load
/// point. Stops early once mean latency exceeds `stop_ms` (saturated far
/// past the SLA) to keep experiment time bounded.
pub fn ramp(
    label: &str,
    ladder: &[usize],
    stop_ms: f64,
    mut run: impl FnMut(usize) -> LoadPoint,
) -> Curve {
    let mut curve = Curve::new(label);
    for &clients in ladder {
        let point = run(clients);
        let lat = point.mean_latency_ms;
        curve.points.push(point);
        if lat > stop_ms {
            break;
        }
    }
    curve
}

/// A geometric client ladder `start, start*factor, ...` capped at `max`.
pub fn ladder(start: usize, factor: f64, max: usize) -> Vec<usize> {
    assert!(factor > 1.0 && start >= 1);
    let mut out = vec![start];
    loop {
        let next = ((*out.last().unwrap() as f64) * factor).ceil() as usize;
        if next > max {
            break;
        }
        out.push(next);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(clients: usize, tput: f64, lat: f64) -> LoadPoint {
        LoadPoint {
            clients,
            throughput: tput,
            mean_latency_ms: lat,
            p50_ms: lat,
            p99_ms: lat * 2.0,
            completed: 100,
        }
    }

    #[test]
    fn peak_respects_sla() {
        let mut c = Curve::new("x");
        c.points = vec![
            point(10, 100.0, 50.0),
            point(20, 180.0, 120.0),
            point(40, 220.0, 900.0),
            point(80, 230.0, 2500.0), // violates 2000ms SLA
        ];
        let p = c.peak(2000.0).unwrap();
        assert_eq!(p.point.clients, 40);
        assert_eq!(p.point.throughput, 220.0);
        assert!(p.met_sla);
    }

    #[test]
    fn peak_falls_back_when_all_violate() {
        let mut c = Curve::new("x");
        c.points = vec![point(10, 10.0, 3000.0), point(20, 12.0, 5000.0)];
        let p = c.peak(2000.0).unwrap();
        assert_eq!(p.point.clients, 10);
        assert!(!p.met_sla, "the all-points-violate fallback must be flagged");
    }

    #[test]
    fn peak_on_empty_curve_is_none() {
        assert!(Curve::new("x").peak(2000.0).is_none());
    }

    #[test]
    fn ramp_stops_after_saturation() {
        let mut calls = 0;
        let curve = ramp("t", &[1, 2, 4, 8, 16], 100.0, |c| {
            calls += 1;
            point(c, c as f64, if c >= 4 { 500.0 } else { 10.0 })
        });
        assert_eq!(calls, 3, "stops after first point above stop_ms");
        assert_eq!(curve.points.len(), 3);
    }

    #[test]
    fn ladder_is_geometric() {
        let l = ladder(5, 2.0, 50);
        assert_eq!(l, vec![5, 10, 20, 40]);
    }

    #[test]
    fn light_load_latency_picks_fewest_clients() {
        let mut c = Curve::new("x");
        c.points = vec![point(20, 10.0, 99.0), point(5, 2.0, 42.0)];
        assert_eq!(c.light_load_latency(), Some(42.0));
    }
}
