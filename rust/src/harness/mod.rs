//! Experiment harness: client ramps, peak-throughput search under a
//! latency SLA (the paper's "peak throughput is the maximum throughput a
//! system can sustain while ensuring an average latency of less than
//! 2000 ms"), and table/figure report rendering.

pub mod experiments;
pub mod report;

use crate::simnet::metrics::SimMetrics;
use crate::util::stats::Summary;

/// One measured load point of a throughput/latency curve.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    pub clients: usize,
    pub throughput: f64,
    pub mean_latency_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub completed: u64,
}

impl LoadPoint {
    pub fn from_summary(clients: usize, throughput: f64, lat: &mut Summary, completed: u64) -> Self {
        LoadPoint {
            clients,
            throughput,
            mean_latency_ms: lat.mean(),
            p50_ms: lat.p50(),
            p99_ms: lat.p99(),
            completed,
        }
    }

    /// Build a point from the mergeable bucketed histograms. Unlike
    /// [`LoadPoint::from_summary`], this is defined in both metric
    /// modes — the exact per-sample `Summary`s are skipped entirely at
    /// [`crate::simnet::ClientsConfig::bucketed`] scale — at the
    /// histogram's ~3% quantile resolution.
    pub fn from_metrics(clients: usize, throughput: f64, m: &SimMetrics) -> Self {
        LoadPoint {
            clients,
            throughput,
            mean_latency_ms: m.latency_hist.mean_ms(),
            p50_ms: m.latency_hist.p50_ms(),
            p99_ms: m.latency_hist.p99_ms(),
            completed: m.completed,
        }
    }
}

/// A measured throughput/latency curve for one system configuration.
#[derive(Debug, Clone, Default)]
pub struct Curve {
    pub label: String,
    pub points: Vec<LoadPoint>,
}

impl Curve {
    pub fn new(label: impl Into<String>) -> Self {
        Curve { label: label.into(), points: Vec::new() }
    }

    /// Peak throughput under the SLA: max throughput among points whose
    /// mean latency stays below `sla_ms`; falls back to the lowest-latency
    /// point when every point violates the SLA.
    pub fn peak(&self, sla_ms: f64) -> Option<&LoadPoint> {
        let ok = self
            .points
            .iter()
            .filter(|p| p.mean_latency_ms < sla_ms)
            .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap());
        ok.or_else(|| {
            self.points
                .iter()
                .min_by(|a, b| a.mean_latency_ms.partial_cmp(&b.mean_latency_ms).unwrap())
        })
    }

    /// Latency at the lightest measured load.
    pub fn light_load_latency(&self) -> Option<f64> {
        self.points
            .iter()
            .min_by_key(|p| p.clients)
            .map(|p| p.mean_latency_ms)
    }
}

/// Ramp a system over a client ladder: `run(clients)` measures one load
/// point. Stops early once mean latency exceeds `stop_ms` (saturated far
/// past the SLA) to keep experiment time bounded.
pub fn ramp(
    label: &str,
    ladder: &[usize],
    stop_ms: f64,
    mut run: impl FnMut(usize) -> LoadPoint,
) -> Curve {
    let mut curve = Curve::new(label);
    for &clients in ladder {
        let point = run(clients);
        let lat = point.mean_latency_ms;
        curve.points.push(point);
        if lat > stop_ms {
            break;
        }
    }
    curve
}

/// A geometric client ladder `start, start*factor, ...` capped at `max`.
pub fn ladder(start: usize, factor: f64, max: usize) -> Vec<usize> {
    assert!(factor > 1.0 && start >= 1);
    let mut out = vec![start];
    loop {
        let next = ((*out.last().unwrap() as f64) * factor).ceil() as usize;
        if next > max {
            break;
        }
        out.push(next);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(clients: usize, tput: f64, lat: f64) -> LoadPoint {
        LoadPoint {
            clients,
            throughput: tput,
            mean_latency_ms: lat,
            p50_ms: lat,
            p99_ms: lat * 2.0,
            completed: 100,
        }
    }

    #[test]
    fn peak_respects_sla() {
        let mut c = Curve::new("x");
        c.points = vec![
            point(10, 100.0, 50.0),
            point(20, 180.0, 120.0),
            point(40, 220.0, 900.0),
            point(80, 230.0, 2500.0), // violates 2000ms SLA
        ];
        let p = c.peak(2000.0).unwrap();
        assert_eq!(p.clients, 40);
        assert_eq!(p.throughput, 220.0);
    }

    #[test]
    fn peak_falls_back_when_all_violate() {
        let mut c = Curve::new("x");
        c.points = vec![point(10, 10.0, 3000.0), point(20, 12.0, 5000.0)];
        let p = c.peak(2000.0).unwrap();
        assert_eq!(p.clients, 10);
    }

    #[test]
    fn ramp_stops_after_saturation() {
        let mut calls = 0;
        let curve = ramp("t", &[1, 2, 4, 8, 16], 100.0, |c| {
            calls += 1;
            point(c, c as f64, if c >= 4 { 500.0 } else { 10.0 })
        });
        assert_eq!(calls, 3, "stops after first point above stop_ms");
        assert_eq!(curve.points.len(), 3);
    }

    #[test]
    fn ladder_is_geometric() {
        let l = ladder(5, 2.0, 50);
        assert_eq!(l, vec![5, 10, 20, 40]);
    }

    #[test]
    fn light_load_latency_picks_fewest_clients() {
        let mut c = Curve::new("x");
        c.points = vec![point(20, 10.0, 99.0), point(5, 2.0, 42.0)];
        assert_eq!(c.light_load_latency(), Some(42.0));
    }
}
