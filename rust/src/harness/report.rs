//! Rendering of benchmark results in the shape of the paper's tables and
//! figures (markdown tables + ASCII curves printed to stdout and captured
//! into bench_output.txt).

use super::{Curve, LoadPoint};

/// Render a markdown table from headers + rows.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let padded: Vec<String> = cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:<w$}", w = w))
            .collect();
        format!("| {} |\n", padded.join(" | "))
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    let dashes: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    out.push_str(&format!("|-{}-|\n", dashes.join("-|-")));
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

/// Render a throughput-vs-latency curve set as rows (the paper's Fig 4/5
/// shape: each point is a load level).
pub fn curves_table(curves: &[Curve]) -> String {
    let mut rows = Vec::new();
    for c in curves {
        for p in &c.points {
            rows.push(vec![
                c.label.clone(),
                p.clients.to_string(),
                format!("{:.1}", p.throughput),
                format!("{:.1}", p.mean_latency_ms),
                format!("{:.1}", p.p99_ms),
            ]);
        }
    }
    table(&["system", "clients", "ops/s", "mean ms", "p99 ms"], &rows)
}

/// Render the Fig-3 shape: peak throughput (+latency at peak) per server
/// count per system.
pub fn scalability_table(
    rows: &[(String, usize, Option<LoadPoint>)],
    sla_ms: f64,
) -> String {
    let data: Vec<Vec<String>> = rows
        .iter()
        .map(|(label, servers, peak)| match peak {
            Some(p) => vec![
                label.clone(),
                servers.to_string(),
                format!("{:.1}", p.throughput),
                format!("{:.1}", p.mean_latency_ms),
                p.clients.to_string(),
            ],
            None => vec![label.clone(), servers.to_string(), "-".into(), "-".into(), "-".into()],
        })
        .collect();
    format!(
        "peak throughput under {sla_ms:.0} ms SLA\n{}",
        table(&["system", "servers", "peak ops/s", "lat@peak ms", "clients"], &data)
    )
}

/// A minimal ASCII scatter of (x=throughput, y=latency) per curve — a
/// visual cross-check of the figure shapes in terminal output.
pub fn ascii_curve(curve: &Curve, width: usize, height: usize) -> String {
    if curve.points.is_empty() {
        return String::new();
    }
    let max_x = curve.points.iter().map(|p| p.throughput).fold(1.0f64, f64::max);
    let max_y = curve.points.iter().map(|p| p.mean_latency_ms).fold(1.0f64, f64::max);
    let mut grid = vec![vec![b' '; width]; height];
    for p in &curve.points {
        let x = ((p.throughput / max_x) * (width - 1) as f64).round() as usize;
        let y = ((p.mean_latency_ms / max_y) * (height - 1) as f64).round() as usize;
        grid[height - 1 - y][x.min(width - 1)] = b'*';
    }
    let mut out = format!("{} (x: 0..{max_x:.0} ops/s, y: 0..{max_y:.0} ms)\n", curve.label);
    for row in grid {
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = table(
            &["sys", "n"],
            &[vec!["elia".into(), "4".into()], vec!["mysql-cluster".into(), "12".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("sys"));
        assert!(lines[3].contains("mysql-cluster"));
        // All lines same width.
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    fn curves_table_renders_points() {
        let mut c = Curve::new("elia-3");
        c.points.push(LoadPoint {
            clients: 10,
            throughput: 123.4,
            mean_latency_ms: 56.7,
            p50_ms: 50.0,
            p99_ms: 99.0,
            completed: 1000,
        });
        let t = curves_table(&[c]);
        assert!(t.contains("elia-3"));
        assert!(t.contains("123.4"));
    }

    #[test]
    fn ascii_curve_has_requested_dims() {
        let mut c = Curve::new("x");
        for i in 1..5 {
            c.points.push(LoadPoint {
                clients: i,
                throughput: i as f64 * 10.0,
                mean_latency_ms: i as f64 * 5.0,
                p50_ms: 0.0,
                p99_ms: 0.0,
                completed: 1,
            });
        }
        let s = ascii_curve(&c, 20, 5);
        assert_eq!(s.lines().count(), 6);
        assert!(s.contains('*'));
    }
}
