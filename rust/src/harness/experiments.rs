//! Pre-packaged experiment runners for every table and figure of the
//! paper's evaluation (§7). Benches call these at full scale; smoke tests
//! call them with `quick = true`.
//!
//! Calibration note (DESIGN.md §1, substitution 3): servers are 2-worker
//! stations (T2.medium), operations cost ~5 ms of service time, and
//! message latencies follow Table 2. Absolute throughputs therefore
//! differ from the authors' testbed; the *shapes* — who wins, by what
//! factor, where the knees sit — are the reproduction target.

use crate::analysis::drift::{AdaptiveConfig, DriftConfig, DriftKind};
use crate::baselines::{BaselineConfig, BaselineMode, BaselineSim};
use crate::cluster::{ClusterConfig, ClusterSim};
use crate::conveyor::{ConveyorConfig, ConveyorSim};
use crate::simnet::clients::ClientsConfig;
use crate::simnet::latency::Topology;
use crate::util::VTime;
use crate::workload::analyzed::AnalyzedApp;
use crate::workload::generator::{OpGenerator, ServiceModel};
use crate::workload::{micro, rubis, tpcw};

use super::{ladder, ramp, Curve, LoadPoint};

/// Which macro workload an experiment drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    Tpcw,
    Rubis,
}

impl Workload {
    pub fn name(&self) -> &'static str {
        match self {
            Workload::Tpcw => "TPC-W",
            Workload::Rubis => "RUBiS",
        }
    }

    pub fn analyzed(&self) -> AnalyzedApp {
        self.analyzed_with(true)
    }

    /// `confluence = false` reproduces the conflict-only classification
    /// (the paper's exact Table 1); `true` includes the
    /// invariant-confluence pass.
    pub fn analyzed_with(&self, confluence: bool) -> AnalyzedApp {
        match (self, confluence) {
            (Workload::Tpcw, true) => tpcw::analyzed(),
            (Workload::Tpcw, false) => tpcw::analyzed_no_confluence(),
            (Workload::Rubis, true) => rubis::analyzed(),
            (Workload::Rubis, false) => rubis::analyzed_no_confluence(),
        }
    }

    pub fn generator(&self, app: &AnalyzedApp, max_sites: usize) -> Box<dyn OpGenerator> {
        self.generator_for(app, max_sites, 0)
    }

    /// One generator per client group: group `g` gets id/RNG stream `g`
    /// (stream 0 is the default, so group 0 matches
    /// [`Workload::generator`]), keeping fresh-id ranges disjoint across
    /// groups. These macro generators carry mutable id counters, so
    /// their operation sequences are deterministic only at a *fixed*
    /// group count — the bit-identical K-invariance guarantee holds for
    /// rng-pure generators (see `simnet/README.md`).
    pub fn generator_for(
        &self,
        app: &AnalyzedApp,
        max_sites: usize,
        group: usize,
    ) -> Box<dyn OpGenerator> {
        match self {
            Workload::Tpcw => Box::new(
                tpcw::TpcwGenerator::new(app, tpcw::TpcwScale::default(), max_sites)
                    .with_stream(group as u64),
            ),
            Workload::Rubis => Box::new(
                rubis::RubisGenerator::new(app, rubis::RubisScale::default())
                    .with_stream(group as u64),
            ),
        }
    }

    /// Seed one server's database at the generators' default scale — the
    /// live served runs ([`fig3_live`], `elia serve`); the simulators
    /// seed through their own hooks.
    pub fn seed_db(&self, db: &crate::db::Db) {
        match self {
            Workload::Tpcw => tpcw::seed(db, tpcw::TpcwScale::default()),
            Workload::Rubis => rubis::seed(db, rubis::RubisScale::default()),
        }
    }
}

/// Global experiment scale knobs.
///
/// `think_ms` defaults to ~1 s for the macro benchmarks: TPC-W/RUBiS
/// emulate web browsers with think times (the TPC-W spec uses several
/// seconds). This is what makes the paper's Table 3 consistent: a
/// centralized server queues into the ~1.4 s regime at a load the
/// five-site Eliá deployment absorbs at intra-site latency.
#[derive(Debug, Clone, Copy)]
pub struct ExpScale {
    pub warmup_s: u64,
    pub horizon_s: u64,
    pub max_clients: usize,
    pub think_ms: f64,
    /// Worker threads for the window-parallel simulators (plumbed into
    /// `ConveyorConfig`, `ClusterConfig` and `BaselineConfig`
    /// `::parallel`): 1 = sequential, 0 = all cores. Results are
    /// bit-identical for every value (see
    /// `tests/parallel_determinism.rs`), so benches default to all
    /// cores via their `--parallel` flag.
    pub parallel: usize,
    /// Client groups the client tier is sharded into (plumbed into
    /// [`ClientsConfig::groups`]): 1 = single group (default), 0 = one
    /// per available core. Groups are scheduled over the same worker
    /// pool as the servers, so this is what lets million-client tiers
    /// drain in parallel.
    pub client_groups: usize,
}

impl ExpScale {
    pub fn full() -> Self {
        ExpScale {
            warmup_s: 4,
            horizon_s: 20,
            max_clients: 16384,
            think_ms: 1000.0,
            parallel: 1,
            client_groups: 1,
        }
    }

    pub fn quick() -> Self {
        ExpScale {
            warmup_s: 2,
            horizon_s: 8,
            max_clients: 4096,
            think_ms: 1000.0,
            parallel: 1,
            client_groups: 1,
        }
    }

    /// Set the simulator thread budget (0 = all available cores).
    pub fn with_parallel(mut self, threads: usize) -> Self {
        self.parallel = threads;
        self
    }

    /// Set the client-group count (0 = one per available core).
    pub fn with_client_groups(mut self, groups: usize) -> Self {
        self.client_groups = groups;
        self
    }

    /// Client-tier config shared by every experiment at this scale.
    /// Beyond ~128k clients the per-sample `Summary`s are skipped in
    /// favour of the fixed-size bucketed histograms, keeping metrics
    /// memory flat on million-client runs.
    fn clients_cfg(&self, clients: usize) -> ClientsConfig {
        ClientsConfig {
            n: clients,
            think_ms: self.think_ms,
            seed: 0xF16,
            groups: self.client_groups,
            bucketed: clients >= (1 << 17),
            ..Default::default()
        }
    }
}

fn conveyor_point<'a>(
    app: &'a AnalyzedApp,
    topo: Topology,
    clients: usize,
    scale: &ExpScale,
    service: ServiceModel,
    gen: impl FnMut(usize) -> Box<dyn OpGenerator + 'a>,
) -> LoadPoint {
    conveyor_point_with(app, topo, clients, scale, service, gen, None)
}

fn conveyor_point_with<'a>(
    app: &'a AnalyzedApp,
    topo: Topology,
    clients: usize,
    scale: &ExpScale,
    service: ServiceModel,
    gen: impl FnMut(usize) -> Box<dyn OpGenerator + 'a>,
    client_matrix: Option<crate::simnet::latency::LatencyMatrix>,
) -> LoadPoint {
    let cfg = ConveyorConfig {
        service,
        warmup: VTime::from_secs(scale.warmup_s),
        horizon: VTime::from_secs(scale.horizon_s),
        execute_real: false,
        client_matrix,
        parallel: scale.parallel,
        ..Default::default()
    };
    let report =
        ConveyorSim::new(app, topo, scale.clients_cfg(clients), cfg, gen, |_| {}).run();
    LoadPoint::from_metrics(clients, report.throughput(), &report.metrics)
}

fn cluster_point<'a>(
    app: &'a AnalyzedApp,
    topo: Topology,
    clients: usize,
    scale: &ExpScale,
    service: ServiceModel,
    gen: impl FnMut(usize) -> Box<dyn OpGenerator + 'a>,
) -> LoadPoint {
    let cfg = ClusterConfig {
        service,
        warmup: VTime::from_secs(scale.warmup_s),
        horizon: VTime::from_secs(scale.horizon_s),
        parallel: scale.parallel,
        ..Default::default()
    };
    let report = ClusterSim::new(app, topo, scale.clients_cfg(clients), cfg, gen).run();
    LoadPoint::from_metrics(clients, report.throughput(), &report.metrics)
}

fn baseline_point<'a>(
    app: &'a AnalyzedApp,
    mode: BaselineMode,
    client_sites: usize,
    clients: usize,
    scale: &ExpScale,
    service: ServiceModel,
    gen: impl FnMut(usize) -> Box<dyn OpGenerator + 'a>,
) -> LoadPoint {
    baseline_point_on(
        app,
        mode,
        Topology::wan_full_client(client_sites),
        clients,
        scale,
        service,
        gen,
    )
}

/// Like [`baseline_point`] but over an explicit client-site latency
/// matrix — fig3 runs the Warp baseline on the LAN topology, where the
/// WAN-only default would misprice every hop.
fn baseline_point_on<'a>(
    app: &'a AnalyzedApp,
    mode: BaselineMode,
    sites: crate::simnet::latency::LatencyMatrix,
    clients: usize,
    scale: &ExpScale,
    service: ServiceModel,
    gen: impl FnMut(usize) -> Box<dyn OpGenerator + 'a>,
) -> LoadPoint {
    let cfg = BaselineConfig {
        mode,
        service,
        warmup: VTime::from_secs(scale.warmup_s),
        horizon: VTime::from_secs(scale.horizon_s),
        parallel: scale.parallel,
        ..BaselineConfig::centralized()
    };
    let report = BaselineSim::new(app, sites, scale.clients_cfg(clients), cfg, gen).run();
    LoadPoint::from_metrics(clients, report.throughput(), &report.metrics)
}

/// Figure 3 — LAN scalability: (system, servers, curve) for each server
/// count; peaks are extracted with the paper's 2000 ms SLA.
pub fn fig3(workload: Workload, servers: &[usize], scale: &ExpScale) -> Vec<(String, usize, Curve)> {
    let app = workload.analyzed();
    let service = ServiceModel::default();
    let mut out = Vec::new();
    for &n in servers {
        let clients = ladder(n * 16, 2.0, scale.max_clients);
        let elia = ramp(&format!("elia-{n}"), &clients, 4000.0, |c| {
            conveyor_point(&app, Topology::lan(n), c, scale, service, |g| {
                workload.generator_for(&app, n, g)
            })
        });
        out.push(("elia".to_string(), n, elia));
        let cluster = ramp(&format!("mysql-cluster-{n}"), &clients, 4000.0, |c| {
            cluster_point(&app, Topology::lan(n), c, scale, service, |g| {
                workload.generator_for(&app, n, g)
            })
        });
        out.push(("mysql-cluster".to_string(), n, cluster));
        let warp = ramp(&format!("warp-{n}"), &clients, 4000.0, |c| {
            baseline_point_on(
                &app,
                BaselineMode::Warp { n_servers: n },
                Topology::lan(n).servers,
                c,
                scale,
                service,
                |g| workload.generator_for(&app, n, g),
            )
        });
        out.push(("warp".to_string(), n, warp));
    }
    out
}

/// Figure 4 — WAN throughput/latency curves for Eliá vs centralized vs
/// read-only vs Warp-style acyclic commit, at `n` sites (clients always
/// at 5 sites for the baselines, at `n` sites for Eliá — matching the
/// paper's deployment).
pub fn fig4(workload: Workload, n: usize, scale: &ExpScale) -> Vec<Curve> {
    let app = workload.analyzed();
    let service = ServiceModel::default();
    let clients = ladder(16, 2.0, scale.max_clients);
    let stop = 8000.0; // paper stresses until 5 s latency
    let mut curves = Vec::new();
    curves.push(ramp("centralized", &clients, stop, |c| {
        baseline_point(&app, BaselineMode::Centralized, 5, c, scale, service, |g| {
            workload.generator_for(&app, 5, g)
        })
    }));
    curves.push(ramp(&format!("read-only-{n}"), &clients, stop, |c| {
        baseline_point(&app, BaselineMode::ReadOnly { n_servers: n }, 5, c, scale, service, |g| {
            workload.generator_for(&app, 5, g)
        })
    }));
    curves.push(ramp(&format!("warp-{n}"), &clients, stop, |c| {
        baseline_point(&app, BaselineMode::Warp { n_servers: n }, 5, c, scale, service, |g| {
            workload.generator_for(&app, 5, g)
        })
    }));
    curves.push(ramp(&format!("elia-{n}"), &clients, stop, |c| {
        conveyor_point_with(
            &app,
            Topology::wan(n),
            c,
            scale,
            service,
            |g| workload.generator_for(&app, n, g),
            Some(Topology::wan_full_client(5)),
        )
    }));
    curves
}

/// Table 3 — WAN light-load request latency for each configuration.
/// Returns (config label, mean latency ms).
pub fn table3(workload: Workload, scale: &ExpScale) -> Vec<(String, f64)> {
    let app = workload.analyzed();
    let service = ServiceModel::default();
    // "Light load" matches the paper's Table 3 regime: far below the
    // multi-server systems' saturation, but enough offered load that a
    // single WAN server exhibits its queueing latency (the paper's
    // centralized column shows 1390 ms / 416 ms — clearly not an idle
    // server). We use the lowest rung of the Fig 4 ramp.
    let light = 2048;
    let mut rows = Vec::new();
    let p = baseline_point(&app, BaselineMode::Centralized, 5, light, scale, service, |g| {
        workload.generator_for(&app, 5, g)
    });
    rows.push(("centralized".to_string(), p.mean_latency_ms));
    for n in [2usize, 3, 5] {
        let p = conveyor_point_with(
            &app,
            Topology::wan(n),
            light,
            scale,
            service,
            |g| workload.generator_for(&app, n, g),
            Some(Topology::wan_full_client(5)),
        );
        rows.push((format!("elia-{n}"), p.mean_latency_ms));
    }
    for n in [2usize, 3, 5] {
        let p = baseline_point(
            &app,
            BaselineMode::ReadOnly { n_servers: n },
            5,
            light,
            scale,
            service,
            |g| workload.generator_for(&app, 5, g),
        );
        rows.push((format!("read-only-{n}"), p.mean_latency_ms));
    }
    rows
}

/// Figure 5 — micro: throughput/latency curves at different local-op
/// ratios (WAN, 3 servers, 5 ms ops).
pub fn fig5(ratios: &[f64], scale: &ExpScale) -> Vec<Curve> {
    let app = micro::analyzed();
    let service = ServiceModel::fixed(5.0);
    // Micro clients replay with a short think time (the paper drives raw
    // ops/s); macro experiments use ~1 s think times (web clients).
    let scale = &ExpScale { think_ms: 100.0, ..*scale };
    let clients = ladder(8, 2.0, scale.max_clients);
    ratios
        .iter()
        .map(|&r| {
            ramp(&format!("local={:.0}%", r * 100.0), &clients, 8000.0, |c| {
                conveyor_point(&app, Topology::wan(3), c, scale, service, |_| {
                    Box::new(micro::MicroGenerator::new(&app, r))
                })
            })
        })
        .collect()
}

/// Figure 6 — micro mean latencies (overall, local, global) per ratio at
/// a fixed load. Returns (ratio, overall, local, global).
pub fn fig6(ratios: &[f64], clients: usize, scale: &ExpScale) -> Vec<(f64, f64, f64, f64)> {
    let app = micro::analyzed();
    let service = ServiceModel::fixed(5.0);
    let scale = &ExpScale { think_ms: 100.0, ..*scale };
    ratios
        .iter()
        .map(|&r| {
            let cfg = ConveyorConfig {
                service,
                warmup: VTime::from_secs(scale.warmup_s),
                horizon: VTime::from_secs(scale.horizon_s),
                execute_real: false,
                parallel: scale.parallel,
                ..Default::default()
            };
            let report = ConveyorSim::new(
                &app,
                Topology::wan(3),
                ClientsConfig {
                    n: clients,
                    think_ms: scale.think_ms,
                    seed: 0xF16,
                    ..Default::default()
                },
                cfg,
                |_| Box::new(micro::MicroGenerator::new(&app, r)),
                |_| {},
            )
            .run();
            (
                r,
                report.metrics.latency.mean(),
                report.metrics.local_latency.mean(),
                report.metrics.global_latency.mean(),
            )
        })
        .collect()
}

/// One Table 1 row: name, class counts (the paper's columns plus the
/// confluence pass's CF), read-only count, total, and class frequencies.
pub type Table1Row =
    (String, usize, usize, usize, usize, usize, usize, usize, f64, f64, f64, f64);

/// Table 1 — classification and frequency summary for both benchmarks
/// (invariant-confluence pass included; see [`table1_with`]).
pub fn table1() -> Vec<Table1Row> {
    table1_with(true)
}

/// Table 1 with the confluence pass on or off — `false` pins the
/// paper's original conflict-only counts (the bench's `--no-confluence`).
pub fn table1_with(confluence: bool) -> Vec<Table1Row> {
    [Workload::Tpcw, Workload::Rubis]
        .iter()
        .map(|w| {
            let app = w.analyzed_with(confluence);
            let (l, g, c, lg, cf, ro, total) = app.table1_row();
            let wsum: f64 = app.spec.txns.iter().map(|t| t.weight).sum();
            let freq = |class: crate::analysis::OpClass| -> f64 {
                app.spec
                    .txns
                    .iter()
                    .zip(&app.classification.classes)
                    .filter(|(_, cl)| **cl == class)
                    .map(|(t, _)| t.weight)
                    .sum::<f64>()
                    / wsum
            };
            let ro_freq: f64 = app
                .spec
                .txns
                .iter()
                .filter(|t| t.is_read_only())
                .map(|t| t.weight)
                .sum::<f64>()
                / wsum;
            (
                w.name().to_string(),
                l,
                g,
                c,
                lg,
                cf,
                ro,
                total,
                // Confluent ops execute locally, so they count toward
                // the local frequency alongside L and L/G.
                freq(crate::analysis::OpClass::Local)
                    + freq(crate::analysis::OpClass::LocalGlobal)
                    + freq(crate::analysis::OpClass::Confluent),
                freq(crate::analysis::OpClass::Global),
                freq(crate::analysis::OpClass::Commutative),
                ro_freq,
            )
        })
        .collect()
}

/// Names of the tables on which every replica must converge: tables
/// written *only* by always-replicated operation classes (global /
/// confluent), whose state updates ride the token to every server in
/// one total order. A table also written by local, commutative, or
/// local-global templates legitimately diverges — those writes stay at
/// the routed server (a local/global template replicates only on its
/// global paths), e.g. a cart table with local adds and a global
/// order-time clear. Live convergence checks hash only the converging
/// subset, via [`Db::table_hash`](crate::db::Db::table_hash).
pub fn replicated_tables(app: &AnalyzedApp) -> Vec<String> {
    use crate::analysis::OpClass;
    let mut replicated: Vec<usize> = Vec::new();
    let mut local_written: Vec<usize> = Vec::new();
    for (t, rw) in app.rwsets.iter().enumerate() {
        let dest = match app.class(t) {
            OpClass::Global | OpClass::Confluent => &mut replicated,
            _ => &mut local_written,
        };
        for w in &rw.writes {
            for a in &w.attrs {
                if !dest.contains(&a.table) {
                    dest.push(a.table);
                }
            }
        }
    }
    replicated.retain(|ti| !local_written.contains(ti));
    replicated.sort_unstable();
    replicated.iter().map(|&ti| app.spec.schema.table(ti).name.clone()).collect()
}

/// Fold one server's replicated-table hashes into a single digest
/// (compare across servers for convergence).
pub fn replica_hash(db: &crate::db::Db, tables: &[String]) -> u64 {
    tables
        .iter()
        .fold(0xcbf29ce484222325u64, |acc, t| acc.wrapping_mul(0x100000001b3) ^ db.table_hash(t))
}

/// One arm of the drift experiment ([`fig_drift`]): the per-second
/// belted-fraction curve plus its summary statistics.
#[derive(Debug, Clone)]
pub struct DriftArm {
    /// `"static"` (frozen controller) or `"adaptive"`.
    pub label: String,
    /// Per-second `(belted, coordination-free)` completion counts.
    pub curve: Vec<(u64, u64)>,
    /// Belted fraction before the drift point (steady state of epoch 0).
    pub belted_pre: f64,
    /// Belted fraction over the post-drift steady-state window.
    pub belted_post: f64,
    /// Routing epochs installed by the controller.
    pub epoch_switches: u64,
    /// Version of the last installed epoch (0 = never switched).
    pub final_epoch: u64,
    /// Server-to-server forwards of ops issued under a stale epoch.
    pub redirects: u64,
    /// Completed operations per simulated second.
    pub throughput: f64,
    /// Mean request latency (ms).
    pub mean_latency_ms: f64,
}

fn drift_arm(label: &str, adaptive: AdaptiveConfig, drift: DriftConfig, scale: &ExpScale) -> DriftArm {
    let app = micro::drift_analyzed();
    let horizon_s = scale.horizon_s.max(20);
    let cfg = ConveyorConfig {
        service: ServiceModel::fixed(1.0),
        warmup: VTime::from_secs(1),
        horizon: VTime::from_secs(horizon_s),
        parallel: scale.parallel,
        adaptive: Some(adaptive),
        ..Default::default()
    };
    let report = ConveyorSim::new(
        &app,
        Topology::lan(3),
        ClientsConfig { n: 32, think_ms: 10.0, seed: 0xD21F, ..Default::default() },
        cfg,
        |_| Box::new(micro::DriftGen::new(drift)),
        |_| {},
    )
    .run();
    // Steady-state windows on either side of the drift point: skip the
    // first seconds (warmup / belt fill) and the switch transient.
    let drift_s = match drift.kind {
        DriftKind::FlashCrowd { at_s } => at_s.ceil() as usize,
        DriftKind::Diurnal { period_s } | DriftKind::HotKey { period_s } => {
            (period_s / 2.0).ceil() as usize
        }
    };
    DriftArm {
        label: label.to_string(),
        belted_pre: report.belted_fraction(2, drift_s.saturating_sub(1)),
        belted_post: report.belted_fraction(drift_s + 4, horizon_s as usize),
        curve: report.drift_curve.clone(),
        epoch_switches: report.epoch_switches,
        final_epoch: report.final_epoch,
        redirects: report.redirects,
        throughput: report.throughput(),
        mean_latency_ms: report.mean_latency_ms(),
    }
}

/// The drift figure: the same flash-crowd workload (`micro::DriftGen`)
/// run once with a frozen controller (static routing — the offline
/// partitioning of the original paper) and once with live routing
/// epochs (`analysis::drift`). The reproduction target is the shape:
/// both arms agree before the drift point; after it the static arm's
/// belted fraction jumps (the formerly-local template turned global)
/// while the adaptive arm re-partitions back down. Written to
/// `BENCH_drift.json` by the `drift_adaptive` bench.
pub fn fig_drift(scale: &ExpScale) -> (DriftArm, DriftArm) {
    let drift = DriftConfig::default();
    let frozen = drift_arm("static", AdaptiveConfig::frozen(), drift, scale);
    let adaptive = drift_arm(
        "adaptive",
        AdaptiveConfig { window_rotations: 32, ..Default::default() },
        drift,
        scale,
    );
    (frozen, adaptive)
}

/// One live measurement point: a real served cluster (framed wire
/// protocol, belt token as ring messages) driven by real client threads,
/// as opposed to the modeled [`fig3`] points. Written to
/// `BENCH_live.json` by the `fig3_live` bench.
#[derive(Debug, Clone)]
pub struct LivePoint {
    /// Workload name.
    pub workload: String,
    /// Cluster size.
    pub servers: usize,
    /// Concurrent client threads.
    pub clients: usize,
    /// Operations completed successfully.
    pub ops: u64,
    /// Operations rejected with semantic errors (generated-id
    /// collisions etc. — benign, matching the simulators' tolerance).
    pub errors: u64,
    /// Wall-clock duration of the client phase.
    pub elapsed_s: f64,
    /// Completed operations per wall-clock second.
    pub throughput: f64,
    /// Mean client-observed latency (ms).
    pub mean_ms: f64,
    /// 99th-percentile client-observed latency (ms).
    pub p99_ms: f64,
    /// Operations the servers classified local/commutative.
    pub ops_local: u64,
    /// Operations that parked for the token.
    pub ops_global: u64,
    /// Invariant-confluent operations.
    pub ops_confluent: u64,
    /// Retryable server errors absorbed by the client stubs.
    pub client_retries: u64,
    /// Per-server digest over the replicated tables after shutdown.
    pub replica_hashes: Vec<u64>,
    /// True when every server's digest matches.
    pub converged: bool,
}

/// Run `clients` real client threads against a served loopback cluster
/// of `n_servers` and measure wall-clock throughput/latency — the live
/// counterpart of one [`fig3`] point, with a replica-convergence check
/// at shutdown.
pub fn fig3_live(
    workload: Workload,
    n_servers: usize,
    clients: usize,
    ops_per_client: u64,
) -> LivePoint {
    use crate::net::{ClientConfig, Cluster, Loopback, NetClient, NetError, ServeConfig, Transport};
    use crate::util::Summary;
    use std::sync::Arc;

    let app = Arc::new(workload.analyzed());
    let transport: Arc<dyn Transport> = Arc::new(Loopback::new());
    let cluster = Cluster::start(
        Arc::clone(&app),
        ServeConfig::loopback(n_servers),
        Arc::clone(&transport),
        |db| workload.seed_db(db),
    )
    .expect("cluster start");
    let addrs = cluster.client_addrs().to_vec();

    let start = std::time::Instant::now();
    let mut handles = Vec::new();
    for g in 0..clients {
        let app = Arc::clone(&app);
        let transport = Arc::clone(&transport);
        let addrs = addrs.clone();
        handles.push(std::thread::spawn(move || {
            let mut client =
                NetClient::connect(Arc::clone(&app), transport, addrs, ClientConfig::default())
                    .expect("client connect");
            let mut generator = workload.generator_for(&app, n_servers, g);
            let mut rng = crate::util::Rng::stream(0xF16, g as u64);
            let mut lat = Summary::new();
            let (mut ops, mut errors) = (0u64, 0u64);
            for _ in 0..ops_per_client {
                let op = generator.next_op(&mut rng, g % n_servers, n_servers);
                let t0 = std::time::Instant::now();
                match client.submit(&op) {
                    Ok(_) => {
                        ops += 1;
                        lat.add(t0.elapsed().as_secs_f64() * 1e3);
                    }
                    // Benign semantic errors, same tolerance as the
                    // simulators with real execution.
                    Err(NetError::Server(_)) => errors += 1,
                    Err(NetError::Transport(e)) => panic!("transport failure: {e}"),
                }
            }
            (ops, errors, lat, client.retries)
        }));
    }
    let mut lat = Summary::new();
    let (mut ops, mut errors, mut client_retries) = (0u64, 0u64, 0u64);
    for h in handles {
        let (o, e, l, r) = h.join().expect("client thread");
        ops += o;
        errors += e;
        lat.merge(&l);
        client_retries += r;
    }
    let elapsed_s = start.elapsed().as_secs_f64();
    cluster.shutdown();

    let repl = replicated_tables(&app);
    let replica_hashes: Vec<u64> =
        (0..n_servers).map(|s| replica_hash(cluster.db(s), &repl)).collect();
    let converged = replica_hashes.windows(2).all(|w| w[0] == w[1]);
    use std::sync::atomic::Ordering;
    let (mut ops_local, mut ops_global, mut ops_confluent) = (0u64, 0u64, 0u64);
    for s in 0..n_servers {
        let node = cluster.node(s);
        ops_local += node.ops_local.load(Ordering::Relaxed);
        ops_global += node.ops_global.load(Ordering::Relaxed);
        ops_confluent += node.ops_confluent.load(Ordering::Relaxed);
    }
    LivePoint {
        workload: workload.name().to_string(),
        servers: n_servers,
        clients,
        ops,
        errors,
        elapsed_s,
        throughput: if elapsed_s > 0.0 { ops as f64 / elapsed_s } else { 0.0 },
        mean_ms: lat.mean(),
        p99_ms: lat.p99(),
        ops_local,
        ops_global,
        ops_confluent,
        client_retries,
        replica_hashes,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig3_shape_elia_beats_cluster() {
        let scale = ExpScale::quick();
        let rows = fig3(Workload::Rubis, &[3], &scale);
        assert_eq!(rows.len(), 3, "elia, mysql-cluster and warp per server count");
        let peak = |name: &str| {
            rows.iter()
                .find(|(s, _, _)| s == name)
                .unwrap_or_else(|| panic!("missing {name} curve"))
                .2
                .peak(2000.0)
                .unwrap()
                .point
                .throughput
        };
        let elia_peak = peak("elia");
        let cluster_peak = peak("mysql-cluster");
        assert!(
            elia_peak > cluster_peak,
            "elia {elia_peak} must beat cluster {cluster_peak} on RUBiS"
        );
        assert!(peak("warp") > 0.0, "warp baseline curve must produce a peak");
    }

    #[test]
    fn quick_table3_elia5_beats_centralized() {
        let scale = ExpScale::quick();
        let rows = table3(Workload::Rubis, &scale);
        let get = |label: &str| rows.iter().find(|(l, _)| l == label).unwrap().1;
        let cen = get("centralized");
        let elia5 = get("elia-5");
        assert!(
            elia5 * 2.0 < cen,
            "elia-5 ({elia5:.0}ms) must be far below centralized ({cen:.0}ms)"
        );
    }

    #[test]
    fn quick_fig6_global_latency_exceeds_local() {
        let scale = ExpScale::quick();
        let rows = fig6(&[0.5], 20, &scale);
        let (_, overall, local, global) = rows[0];
        assert!(global > local * 1.5, "global {global} vs local {local}");
        assert!(overall > local && overall < global);
    }

    #[test]
    fn table1_has_both_workloads() {
        // Conflict-only mode pins the paper's exact Table 1 counts.
        let rows = table1_with(false);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "TPC-W");
        assert_eq!((rows[0].1, rows[0].2, rows[0].3, rows[0].4, rows[0].5), (10, 5, 5, 0, 0));
        assert_eq!((rows[1].1, rows[1].2, rows[1].3, rows[1].4, rows[1].5), (11, 4, 3, 8, 0));
        // The confluence pass widens the coordination-free class on both
        // workloads — strictly more L+C+CF templates than conflict-only.
        let wide = table1();
        for (w, base) in wide.iter().zip(rows.iter()) {
            let free = |r: &Table1Row| r.1 + r.3 + r.5;
            assert!(
                free(w) > free(base),
                "{}: {} vs {} coordination-free",
                w.0,
                free(w),
                free(base)
            );
        }
        assert_eq!((wide[0].2, wide[0].5), (3, 2), "TPC-W: two globals turn confluent");
        assert_eq!((wide[1].4, wide[1].5), (5, 3), "RUBiS: three L/G turn confluent");
    }
}
