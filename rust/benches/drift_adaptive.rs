//! Drift figure — static vs adaptive operation partitioning under a
//! flash-crowd workload shift (live routing epochs, `analysis::drift`).
//!
//! Expected shape: the two arms are indistinguishable before the drift
//! point (both run epoch 0). At t=10s the traffic mix flips from the
//! A-side to the B-side template; the static arm's belted fraction
//! jumps (the still-local template is now the cold one) and stays high,
//! while the adaptive arm's controller observes the new mix, re-runs
//! the partitioner over the token, and installs an epoch that makes the
//! hot template local again — its steady-state belted fraction returns
//! to the pre-drift level. Writes `BENCH_drift.json`.

use elia::harness::experiments::{fig_drift, DriftArm, ExpScale};
use elia::harness::report;
use elia::simnet::parallel::resolve_threads;
use elia::util::cli::Args;

fn write_json(results: &[(String, f64)], path: &str) {
    let mut s = String::from("{\n");
    for (i, (name, v)) in results.iter().enumerate() {
        let sep = if i + 1 < results.len() { "," } else { "" };
        s.push_str(&format!("  \"{}\": {:.4}{}\n", name.replace('"', "'"), v, sep));
    }
    s.push_str("}\n");
    match std::fs::write(path, &s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nwarning: could not write {path}: {e}"),
    }
}

fn main() {
    let args = Args::from_env();
    // Simulator worker threads; 0 (the default) = all available cores.
    let par = args.get_parse("parallel", 0usize);
    let quick = std::env::var("ELIA_BENCH_QUICK").is_ok();
    let scale =
        (if quick { ExpScale::quick() } else { ExpScale::full() }).with_parallel(par);
    println!("[drift simulator threads: {}]", resolve_threads(par));

    let t0 = std::time::Instant::now();
    let (fixed, adaptive) = fig_drift(&scale);

    println!("\n=== Drift — belted fraction, static vs adaptive (LAN, 3 servers) ===");
    let row = |a: &DriftArm| {
        vec![
            a.label.clone(),
            format!("{:.3}", a.belted_pre),
            format!("{:.3}", a.belted_post),
            format!("{}", a.epoch_switches),
            format!("{}", a.final_epoch),
            format!("{}", a.redirects),
            format!("{:.0}", a.throughput),
            format!("{:.1}", a.mean_latency_ms),
        ]
    };
    println!(
        "{}",
        report::table(
            &["arm", "belted pre", "belted post", "switches", "epoch", "redirects", "ops/s", "mean ms"],
            &[row(&fixed), row(&adaptive)],
        )
    );

    // Per-second curves (belted/total), the figure's raw series.
    println!("\nper-second belted fraction (static | adaptive):");
    let frac = |c: &[(u64, u64)], s: usize| -> f64 {
        match c.get(s) {
            Some(&(g, l)) if g + l > 0 => g as f64 / (g + l) as f64,
            _ => 0.0,
        }
    };
    let secs = fixed.curve.len().max(adaptive.curve.len());
    for s in 0..secs {
        println!(
            "  t={s:>2}s  {:.3} | {:.3}",
            frac(&fixed.curve, s),
            frac(&adaptive.curve, s)
        );
    }

    let results = vec![
        ("static_belted_pre".to_string(), fixed.belted_pre),
        ("static_belted_post".to_string(), fixed.belted_post),
        ("adaptive_belted_pre".to_string(), adaptive.belted_pre),
        ("adaptive_belted_post".to_string(), adaptive.belted_post),
        ("adaptive_epoch_switches".to_string(), adaptive.epoch_switches as f64),
        ("adaptive_final_epoch".to_string(), adaptive.final_epoch as f64),
        ("adaptive_redirects".to_string(), adaptive.redirects as f64),
        ("static_throughput".to_string(), fixed.throughput),
        ("adaptive_throughput".to_string(), adaptive.throughput),
        ("static_mean_ms".to_string(), fixed.mean_latency_ms),
        ("adaptive_mean_ms".to_string(), adaptive.mean_latency_ms),
    ];
    write_json(&results, "BENCH_drift.json");
    println!("[drift took {:.1}s]", t0.elapsed().as_secs_f64());
}
