//! Table 3 — WAN request latency under light load, per configuration:
//! centralized, Eliá-{2,3,5}, read-only-{2,3,5}; improvement factors are
//! reported relative to the centralized case, as in the paper.
//!
//! Expected shape: Eliá-5 sits near intra-site latency (tens of ms) while
//! the centralized server queues into the second range; Eliá's factor
//! exceeds the read-only baseline's at every size.

use elia::harness::experiments::{table3, ExpScale, Workload};
use elia::harness::report;

fn main() {
    let quick = std::env::var("ELIA_BENCH_QUICK").is_ok();
    let scale = if quick { ExpScale::quick() } else { ExpScale::full() };
    for workload in [Workload::Tpcw, Workload::Rubis] {
        let t0 = std::time::Instant::now();
        println!("\n=== Table 3 ({}) — WAN light-load latency ===", workload.name());
        let rows = table3(workload, &scale);
        let centralized = rows
            .iter()
            .find(|(l, _)| l == "centralized")
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN);
        let data: Vec<Vec<String>> = rows
            .iter()
            .map(|(label, ms)| {
                vec![
                    label.clone(),
                    format!("{ms:.0}ms"),
                    if label == "centralized" {
                        "-".into()
                    } else {
                        format!("({:.1}x)", centralized / ms)
                    },
                ]
            })
            .collect();
        println!("{}", report::table(&["configuration", "latency", "vs centralized"], &data));
        println!("[table3 {} took {:.1}s]", workload.name(), t0.elapsed().as_secs_f64());
    }
}
