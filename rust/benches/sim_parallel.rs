//! Single- vs multi-thread simulator benchmark (ROADMAP bench-tracking
//! item for the parallel window engine).
//!
//! Runs quick Conveyor points (modeled + real-execution, where the
//! per-server DB work dominates and parallelism pays most) plus the
//! Cluster and Baseline simulators — all three now share the window
//! engine — at 1 thread and at all available cores, verifies the
//! results are identical (they must be — see
//! `tests/parallel_determinism.rs`), and writes wall-clock numbers to
//! `BENCH_sim.json`.
//!
//! Three scaling lines ride along:
//! * a **million-client** Conveyor point (sharded client groups +
//!   bucketed metrics), 1 thread vs all cores;
//! * an **open-loop overload curve** (Poisson arrivals past a
//!   centralized server's capacity — a regime the closed loop cannot
//!   reach);
//! * a **lock-shard sweep** over `LockManager::new(s)` (the
//!   `ELIA_LOCK_SHARDS` tuning axis);
//! * a **recovery curve** (durability tier): kill one server at
//!   increasing crash times — WAL replay makes downtime grow with
//!   uptime; the 2PC baseline answers the same crash with an abort
//!   storm.

use elia::baselines::{BaselineConfig, BaselineMode, BaselineSim};
use elia::cluster::{ClusterConfig, ClusterSim};
use elia::conveyor::{ConveyorConfig, ConveyorSim};
use elia::db::lockmgr::{LockMode, LockTarget};
use elia::db::LockManager;
use elia::harness::experiments::{fig3, ExpScale, Workload};
use elia::simnet::clients::ClientsConfig;
use elia::simnet::crash::CrashConfig;
use elia::simnet::latency::Topology;
use elia::simnet::parallel::available_threads;
use elia::util::VTime;
use elia::workload::generator::ServiceModel;
use elia::workload::micro;
use std::sync::Arc;
use std::time::Instant;

fn write_json(results: &[(String, f64)], path: &str) {
    let mut s = String::from("{\n");
    for (i, (name, v)) in results.iter().enumerate() {
        let sep = if i + 1 < results.len() { "," } else { "" };
        s.push_str(&format!("  \"{}\": {:.1}{}\n", name.replace('"', "'"), v, sep));
    }
    s.push_str("}\n");
    match std::fs::write(path, &s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nwarning: could not write {path}: {e}"),
    }
}

/// One modeled-execution micro point (fig5/fig6 shape: WAN 3 servers).
fn micro_point(threads: usize) -> (f64, u64) {
    let app = micro::analyzed();
    let cfg = ConveyorConfig {
        service: ServiceModel::fixed(5.0),
        warmup: VTime::from_secs(2),
        horizon: VTime::from_secs(8),
        parallel: threads,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = ConveyorSim::new(
        &app,
        Topology::wan(3),
        ClientsConfig { n: 512, think_ms: 100.0, seed: 0xF16, ..Default::default() },
        cfg,
        |_| Box::new(micro::MicroGenerator::new(&app, 0.7)),
        |_| {},
    )
    .run();
    (t0.elapsed().as_secs_f64(), r.metrics.completed)
}

/// One real-execution point: per-server DBMS work dominates, which is
/// the case intra-run parallelism targets.
fn real_point(threads: usize) -> (f64, u64) {
    let app = micro::analyzed();
    let cfg = ConveyorConfig {
        service: ServiceModel::fixed(5.0),
        execute_real: true,
        warmup: VTime::from_secs(1),
        horizon: VTime::from_secs(6),
        parallel: threads,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = ConveyorSim::new(
        &app,
        Topology::lan(4),
        ClientsConfig { n: 96, think_ms: 5.0, seed: 0xF16, ..Default::default() },
        cfg,
        |_| Box::new(micro::MicroGenerator::new(&app, 0.7)),
        micro::seed,
    )
    .run();
    (t0.elapsed().as_secs_f64(), r.metrics.completed)
}

/// The Fig-3 cluster baseline on the window engine: LAN, 6 shards, a
/// write-heavy mix — lock-shard work and 2PC message fan-out spread
/// across server groups.
fn cluster_point(threads: usize) -> (f64, u64) {
    let app = micro::analyzed();
    let cfg = ClusterConfig {
        service: ServiceModel::fixed(5.0),
        warmup: VTime::from_secs(2),
        horizon: VTime::from_secs(8),
        parallel: threads,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = ClusterSim::new(
        &app,
        Topology::lan(6),
        ClientsConfig { n: 512, think_ms: 100.0, seed: 0xF16, ..Default::default() },
        cfg,
        |_| Box::new(micro::MicroGenerator::new(&app, 0.7)),
    )
    .run();
    // Checksum folds both counters injectively (lock_waits stays far
    // below the multiplier), so compensating divergence cannot cancel.
    (t0.elapsed().as_secs_f64(), r.metrics.completed * 1_000_003 + r.lock_waits)
}

/// The Fig-4 read-only baseline on the window engine: five WAN replica
/// groups plus async write replication.
fn baseline_point(threads: usize) -> (f64, u64) {
    let app = micro::analyzed();
    let cfg = BaselineConfig {
        mode: BaselineMode::ReadOnly { n_servers: 5 },
        service: ServiceModel::fixed(5.0),
        warmup: VTime::from_secs(2),
        horizon: VTime::from_secs(8),
        parallel: threads,
        ..BaselineConfig::centralized()
    };
    let t0 = Instant::now();
    let r = BaselineSim::new(
        &app,
        Topology::wan_full_client(5),
        ClientsConfig { n: 512, think_ms: 100.0, seed: 0xF16, ..Default::default() },
        cfg,
        |_| Box::new(micro::MicroGenerator::new(&app, 0.7)),
    )
    .run();
    (t0.elapsed().as_secs_f64(), r.metrics.completed)
}

/// Spawn-overhead probe (ISSUE 5): a modeled fig3-shaped Conveyor point
/// (LAN, 6 servers, the Fig-3 workload mix). Modeled execution does
/// almost no per-event work, so wall clock here is dominated by
/// per-window coordination — exactly the cost the persistent worker
/// pool moves from an OS thread spawn per window to a park/unpark.
/// Reported as windows-per-second at 1 thread vs all cores.
fn spawn_overhead_point(threads: usize) -> (f64, u64, u64) {
    let app = micro::analyzed();
    let cfg = ConveyorConfig {
        service: ServiceModel::fixed(5.0),
        warmup: VTime::from_secs(2),
        horizon: VTime::from_secs(8),
        parallel: threads,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = ConveyorSim::new(
        &app,
        Topology::lan(6),
        ClientsConfig { n: 512, think_ms: 100.0, seed: 0xF16, ..Default::default() },
        cfg,
        |_| Box::new(micro::MicroGenerator::new(&app, 0.7)),
        |_| {},
    )
    .run();
    (t0.elapsed().as_secs_f64(), r.windows, r.metrics.completed)
}

/// Million-client Conveyor point (the tentpole scaling scenario): the
/// client tier is sharded into 8 groups that drain over the worker
/// pool, first issues are lazily released (no million-event boot
/// backlog), issued accounting is O(1), and the bucketed histograms
/// keep metrics memory flat. 8 groups at *both* thread counts, so the
/// checksum comparison is exact even with the stateful-generator
/// caveat out of play.
fn million_point(threads: usize) -> (f64, u64) {
    let app = micro::analyzed();
    let cfg = ConveyorConfig {
        service: ServiceModel::fixed(0.05),
        warmup: VTime::from_secs(2),
        horizon: VTime::from_secs(6),
        parallel: threads,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = ConveyorSim::new(
        &app,
        Topology::lan(8),
        ClientsConfig {
            n: 1_000_000,
            think_ms: 5000.0,
            seed: 0xF16,
            groups: 8,
            bucketed: true,
            ..Default::default()
        },
        cfg,
        |_| Box::new(micro::MicroGenerator::new(&app, 0.7)),
        |_| {},
    )
    .run();
    (t0.elapsed().as_secs_f64(), r.metrics.completed)
}

/// Open-loop overload curve: Poisson arrivals at `rate` ops/s per
/// client against a centralized WAN server (~1600 ops/s capacity at
/// 5 ms/op × 8 workers). Returns (throughput, mean latency ms).
fn open_loop_point(rate: Option<f64>) -> (f64, f64) {
    let app = micro::analyzed();
    let cfg = BaselineConfig {
        service: ServiceModel::fixed(5.0),
        warmup: VTime::from_secs(2),
        horizon: VTime::from_secs(10),
        ..BaselineConfig::centralized()
    };
    let r = BaselineSim::new(
        &app,
        Topology::wan_full_client(5),
        ClientsConfig {
            n: 100,
            think_ms: 50.0,
            seed: 0xF16,
            arrival_rate: rate,
            ..Default::default()
        },
        cfg,
        |_| Box::new(micro::MicroGenerator::new(&app, 0.7)),
    )
    .run();
    (r.throughput(), r.mean_latency_ms())
}

/// Recovery-time curve point: kill conveyor server 1 at `at_secs` into
/// a LAN-4 run. The server's modeled WAL grows with uptime, so the
/// replay charge — and with it the belt stall — grows with the crash
/// time: the durability tier's recovery-cost curve. Returns (downtime
/// ms, replayed records, completed ops).
fn conveyor_crash_point(at_secs: u64) -> (f64, u64, u64) {
    let app = micro::analyzed();
    let cfg = ConveyorConfig {
        service: ServiceModel::fixed(5.0),
        warmup: VTime::from_secs(2),
        horizon: VTime::from_secs(12),
        crash: Some(CrashConfig {
            server: 1,
            at: VTime::from_secs(at_secs),
            ..Default::default()
        }),
        ..Default::default()
    };
    let r = ConveyorSim::new(
        &app,
        Topology::lan(4),
        ClientsConfig { n: 256, think_ms: 50.0, seed: 0xF16, ..Default::default() },
        cfg,
        |_| Box::new(micro::MicroGenerator::new(&app, 0.7)),
        |_| {},
    )
    .run();
    let o = r.crash.expect("crash outcome");
    (o.downtime_ms(), o.replayed_records, r.metrics.completed)
}

/// The 2PC counterpart: the same crash against the cluster baseline
/// with a prepare-round timeout. Where the conveyor stalls and resumes,
/// the cluster coordinators time out — the failure shows up as an abort
/// storm. Returns (downtime ms, aborts, completed ops).
fn cluster_crash_point(at_secs: u64) -> (f64, u64, u64) {
    let app = micro::analyzed();
    let cfg = ClusterConfig {
        service: ServiceModel::fixed(5.0),
        warmup: VTime::from_secs(2),
        horizon: VTime::from_secs(12),
        crash: Some(CrashConfig {
            server: 1,
            at: VTime::from_secs(at_secs),
            ..Default::default()
        }),
        txn_timeout_ms: Some(400.0),
        ..Default::default()
    };
    let r = ClusterSim::new(
        &app,
        Topology::lan(4),
        ClientsConfig { n: 256, think_ms: 50.0, seed: 0xF16, ..Default::default() },
        cfg,
        |_| Box::new(micro::MicroGenerator::new(&app, 0.7)),
    )
    .run();
    let o = r.crash.expect("crash outcome");
    (o.downtime_ms(), r.aborts, r.metrics.completed)
}

/// Lock-shard sweep (the `ELIA_LOCK_SHARDS` tuning axis): 8 threads
/// hammer disjoint keys with X acquire/release pairs, so all measured
/// contention is on the shard mutexes themselves. Returns pairs/s.
fn lock_shard_point(shards: usize) -> f64 {
    const THREADS: u64 = 8;
    const PAIRS: u64 = 100_000;
    let lm = Arc::new(LockManager::new(shards));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let lm = Arc::clone(&lm);
            std::thread::spawn(move || {
                for i in 0..PAIRS {
                    // Disjoint per-thread key ranges: no lock conflicts.
                    let target = LockTarget::Row(0, t * 1_000_000 + (i % 1024));
                    let txn = t * 1_000_000_000 + i;
                    lm.acquire(txn, target, LockMode::X).unwrap();
                    lm.release(txn, &[target]);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(lm.entry_count(), 0);
    (THREADS * PAIRS) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let cores = available_threads();
    let mut results: Vec<(String, f64)> = Vec::new();
    println!("sim_parallel: 1 thread vs {cores} cores\n");

    for (name, f) in [
        ("sim: micro wan3 modeled", micro_point as fn(usize) -> (f64, u64)),
        ("sim: micro lan4 real-exec", real_point),
        ("sim: cluster lan6 2pc", cluster_point),
        ("sim: baseline wan5 read-only", baseline_point),
    ] {
        let (w1, c1) = f(1);
        let (wn, cn) = f(0);
        assert_eq!(c1, cn, "{name}: thread counts must not change results");
        println!(
            "{name:<34} 1T {w1:>7.2}s   {cores}T {wn:>7.2}s   speedup {:.2}x   (check {c1})",
            w1 / wn
        );
        results.push((format!("{name} (1T wall ns)"), w1 * 1e9));
        results.push((format!("{name} ({cores}T wall ns)"), wn * 1e9));
        results.push((format!("{name} (speedup x1000)"), w1 / wn * 1000.0));
    }

    // Spawn overhead: per-window coordination throughput of the engine,
    // 1 thread (no pool) vs all cores (persistent pool dispatch).
    {
        let (w1, win1, c1) = spawn_overhead_point(1);
        let (wn, winn, cn) = spawn_overhead_point(0);
        assert_eq!((win1, c1), (winn, cn), "spawn overhead: results must not change");
        println!(
            "{:<34} {win1} windows   1T {:>9.0} win/s   {cores}T {:>9.0} win/s",
            "sim: spawn overhead fig3 lan6",
            win1 as f64 / w1,
            winn as f64 / wn
        );
        results.push(("sim: spawn overhead fig3 lan6 (1T windows/s)".into(), win1 as f64 / w1));
        results
            .push((format!("sim: spawn overhead fig3 lan6 ({cores}T windows/s)"), winn as f64 / wn));
    }

    // Million-client scaling point: sharded client groups over the
    // worker pool, 1 thread vs all cores.
    {
        let (w1, c1) = million_point(1);
        let (wn, cn) = million_point(0);
        assert_eq!(c1, cn, "million-client: thread counts must not change results");
        println!(
            "{:<34} 1T {w1:>7.2}s   {cores}T {wn:>7.2}s   speedup {:.2}x   (check {c1})",
            "sim: conveyor 1M clients lan8",
            w1 / wn
        );
        results.push(("sim: conveyor 1M clients lan8 (1T wall ns)".into(), w1 * 1e9));
        results.push((format!("sim: conveyor 1M clients lan8 ({cores}T wall ns)"), wn * 1e9));
        results.push(("sim: conveyor 1M clients lan8 (speedup x1000)".into(), w1 / wn * 1000.0));
    }

    // Open-loop overload curve vs the closed-loop reference: past the
    // server's ~1600 ops/s capacity, Poisson arrivals keep coming and
    // latency grows with the standing queue — a curve the reply-gated
    // closed loop cannot produce.
    {
        let (ct, cl) = open_loop_point(None);
        println!("\nsim: open-loop overload (centralized wan5, 100 clients)");
        println!("  closed loop (think 50ms)      {ct:>7.0} ops/s   mean {cl:>9.1} ms");
        for rate in [10.0, 16.0, 20.0, 30.0] {
            let (t, l) = open_loop_point(Some(rate));
            println!("  open loop {rate:>4.0} ops/s/client   {t:>7.0} ops/s   mean {l:>9.1} ms");
            results.push((format!("sim: open-loop rate {rate:.0} (mean latency us)"), l * 1e3));
        }
        results.push(("sim: closed-loop reference (mean latency us)".into(), cl * 1e3));
    }

    // Lock-shard sweep: how the ELIA_LOCK_SHARDS knob trades mutex
    // contention for memory/footprint on the real lock table.
    {
        println!("\nsim: lock-shard sweep (8 threads, disjoint keys)");
        for shards in [1usize, 8, 32, 128] {
            let rate = lock_shard_point(shards);
            println!("  shards {shards:>4}   {rate:>12.0} acquire+release/s");
            results.push((format!("lockmgr: {shards} shards (pairs/s)"), rate));
        }
    }

    // Recovery-time curve (durability tier): the crashed server's WAL
    // grows with its uptime, so downtime grows with the crash time. The
    // conveyor stalls and resumes; the cluster baseline's coordinators
    // time out and abort instead.
    {
        println!("\nsim: recovery curve (kill server 1, lan4)");
        for at in [3u64, 5, 7, 9] {
            let (down, replayed, completed) = conveyor_crash_point(at);
            println!(
                "  conveyor crash @{at}s   down {down:>7.1} ms   replayed {replayed:>6}   completed {completed}"
            );
            results.push((format!("sim: conveyor crash @{at}s (downtime us)"), down * 1e3));
        }
        let (down, aborts, completed) = cluster_crash_point(5);
        println!(
            "  cluster  crash @5s   down {down:>7.1} ms   aborts {aborts:>6}   completed {completed}"
        );
        results.push(("sim: cluster crash @5s (downtime us)".into(), down * 1e3));
        results.push(("sim: cluster crash @5s (aborts)".into(), aborts as f64));
    }

    // A quick fig3 point through the harness (the `--parallel` plumbing
    // path the figure benches use).
    {
        let scale = ExpScale::quick().with_parallel(0);
        let t0 = Instant::now();
        let rows = fig3(Workload::Rubis, &[3], &scale);
        let wall = t0.elapsed().as_secs_f64();
        println!("{:<34} {wall:>7.2}s wall (rows={})", "sim: fig3 quick point (allT)", rows.len());
        results.push(("sim: fig3 rubis n=3 quick (allT wall ns)".into(), wall * 1e9));
    }

    write_json(&results, "BENCH_sim.json");
}
