//! Figure 5 — microbenchmark: throughput/latency curves for local-op
//! ratios 0%..90% on a 3-site WAN deployment with 5 ms operations.
//!
//! Expected shape (paper §7.3): saturation moves out strongly with the
//! local ratio (paper: knee ~600 ops/s at 30% local vs ~5477 ops/s at
//! 90%).

use elia::harness::experiments::{fig5, ExpScale};
use elia::harness::report;
use elia::simnet::parallel::resolve_threads;
use elia::util::cli::Args;

fn main() {
    let args = Args::from_env();
    // Simulator worker threads; 0 (the default) = all available cores.
    let par = args.get_parse("parallel", 0usize);
    let quick = std::env::var("ELIA_BENCH_QUICK").is_ok();
    let scale =
        (if quick { ExpScale::quick() } else { ExpScale::full() }).with_parallel(par);
    println!("[fig5 simulator threads: {}]", resolve_threads(par));
    let ratios: Vec<f64> = if quick {
        vec![0.3, 0.9]
    } else {
        vec![0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]
    };
    let t0 = std::time::Instant::now();
    println!("\n=== Figure 5 — Eliá with different local operation ratios (WAN, 3 servers) ===");
    let curves = fig5(&ratios, &scale);
    println!("{}", report::curves_table(&curves));
    for c in &curves {
        if let Some(p) = c.peak(5000.0) {
            let note = if p.met_sla { "" } else { "  (SLA never met)" };
            println!("  {}: saturation ~{:.0} ops/s{note}", c.label, p.point.throughput);
        }
    }
    for c in &curves {
        println!("\n{}", report::ascii_curve(c, 60, 10));
    }
    println!("[fig5 took {:.1}s]", t0.elapsed().as_secs_f64());
}
