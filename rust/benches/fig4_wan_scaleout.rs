//! Figure 4 — WAN (geo-distributed) scale-out: throughput/latency curves
//! for Eliá vs the centralized and read-only baselines, TPC-W (4a) and
//! RUBiS (4b), at 2..5 sites.
//!
//! Expected shape (paper §7.2): the centralized server saturates at low
//! throughput and WAN latency; read-only replicas help reads; Eliá cuts
//! latency by another large factor and lifts maximum throughput ~2-3x
//! over read-only at five sites.

use elia::harness::experiments::{fig4, ExpScale, Workload};
use elia::harness::report;
use elia::simnet::parallel::resolve_threads;
use elia::util::cli::Args;

fn main() {
    let args = Args::from_env();
    // Simulator worker threads; 0 (the default) = all available cores.
    // Applies to Eliá and the centralized/read-only baselines alike —
    // all simulators run on the shared window engine.
    let par = args.get_parse("parallel", 0usize);
    let quick = std::env::var("ELIA_BENCH_QUICK").is_ok();
    let scale =
        (if quick { ExpScale::quick() } else { ExpScale::full() }).with_parallel(par);
    println!("[fig4 simulator threads: {}]", resolve_threads(par));
    let sites: Vec<usize> = if quick { vec![3] } else { vec![2, 3, 5] };

    for workload in [Workload::Tpcw, Workload::Rubis] {
        for &n in &sites {
            let t0 = std::time::Instant::now();
            println!("\n=== Figure 4 ({}, {n} sites) — WAN throughput/latency ===", workload.name());
            let curves = fig4(workload, n, &scale);
            println!("{}", report::curves_table(&curves));
            // Max sustained throughput per system (5s latency bound).
            for c in &curves {
                if let Some(p) = c.peak(5000.0) {
                    let note = if p.met_sla { "" } else { "  (SLA never met)" };
                    println!(
                        "  {}: max {:.0} ops/s @ {:.0} ms{note}",
                        c.label, p.point.throughput, p.point.mean_latency_ms
                    );
                }
            }
            println!("[fig4 {} n={n} took {:.1}s]", workload.name(), t0.elapsed().as_secs_f64());
        }
    }
}
