//! Figure 3 — LAN scalability of Eliá vs MySQL-Cluster-style data
//! partitioning: peak throughput (2000 ms SLA) and latency-at-peak as a
//! function of server count, for TPC-W (3a) and RUBiS (3b).
//!
//! Expected shape (paper §7.1): the data-partitioning baseline stops
//! improving around 4 servers (TPC-W) while Eliá keeps scaling and peaks
//! several times higher; the gap is largest on the write-heavy TPC-W mix.

use elia::harness::experiments::{fig3, ExpScale, Workload};
use elia::harness::report;
use elia::simnet::parallel::resolve_threads;
use elia::util::cli::Args;

fn main() {
    let args = Args::from_env();
    // Simulator worker threads; 0 (the default) = all available cores.
    // Applies to *both* sides of the comparison: the Eliá Conveyor sim
    // and the MySQL-Cluster baseline now share the window engine.
    let par = args.get_parse("parallel", 0usize);
    // Client groups for the sharded client tier (0 = one per core).
    let groups = args.get_count("client-groups", 1);
    let quick = std::env::var("ELIA_BENCH_QUICK").is_ok();
    let mut scale = (if quick { ExpScale::quick() } else { ExpScale::full() })
        .with_parallel(par)
        .with_client_groups(groups);
    // Top of the client ladder; underscore-tolerant so the scaling run
    // reads naturally: `--clients 1_000_000`. Beyond ~128k clients the
    // harness switches to flat bucketed metrics automatically.
    scale.max_clients = args.get_count("clients", scale.max_clients);
    println!(
        "[fig3 simulator threads: {}, client groups: {}, max clients: {}]",
        resolve_threads(par),
        groups,
        scale.max_clients
    );
    let servers: Vec<usize> =
        if quick { vec![1, 2, 4] } else { vec![1, 2, 4, 6, 8, 10, 12, 14] };

    for workload in [Workload::Tpcw, Workload::Rubis] {
        let t0 = std::time::Instant::now();
        println!("\n=== Figure 3 ({}) — LAN peak throughput vs servers ===", workload.name());
        let rows = fig3(workload, &servers, &scale);
        let table_rows: Vec<(String, usize, Option<elia::harness::LoadPoint>)> = rows
            .iter()
            // An SLA-violating fallback renders as a missing point, not
            // as a fake peak (Peak::met_sla).
            .map(|(sys, n, curve)| {
                (sys.clone(), *n, curve.peak(2000.0).and_then(|p| p.met_sla.then(|| p.point.clone())))
            })
            .collect();
        println!("{}", report::scalability_table(&table_rows, 2000.0));

        // Headline ratios (paper: up to 4.2x throughput, 58.6x latency for
        // TPC-W; 1.4x / 35.7x for RUBiS).
        let best = |sys: &str| {
            rows.iter()
                .filter(|(s, _, _)| s == sys)
                .filter_map(|(_, _, c)| c.peak(2000.0))
                .filter(|p| p.met_sla)
                .max_by(|a, b| a.point.throughput.partial_cmp(&b.point.throughput).unwrap())
                .map(|p| p.point.clone())
        };
        if let (Some(e), Some(m)) = (best("elia"), best("mysql-cluster")) {
            println!(
                "headline: elia peak {:.0} ops/s vs cluster {:.0} ops/s  ({:.1}x tput, {:.1}x latency at peak)",
                e.throughput,
                m.throughput,
                e.throughput / m.throughput,
                m.mean_latency_ms / e.mean_latency_ms,
            );
        }
        println!("[fig3 {} took {:.1}s]", workload.name(), t0.elapsed().as_secs_f64());
    }
}
