//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. MAP misrouting — cost of clients not knowing the partitioning.
//! 2. RUBiS co-location — how the runtime local/global split of the
//!    double-key scheme drives performance (paper §3.1's multi-parameter
//!    partitioning is only useful when keys actually co-locate).
//! 3. strict-reads extraction — the sound over-approximation of read
//!    sets (WHERE columns included) vs the paper's projection-only rule,
//!    and its effect on classification.
//! 4. weight-aware partitioning — Algorithm 1's weighted cost vs
//!    uniform weights (weight(t) = 1).

use elia::analysis::rwsets::ExtractOptions;
use elia::analysis::partition::PartitionOptions;
use elia::harness::report;
use elia::simnet::clients::ClientsConfig;
use elia::simnet::latency::Topology;
use elia::util::VTime;
use elia::workload::analyzed::AnalyzedApp;
use elia::workload::generator::ServiceModel;
use elia::workload::spec::AppSpec;
use elia::workload::{micro, rubis};
use elia::conveyor::{ConveyorConfig, ConveyorSim};

fn run_micro(misroute: f64) -> (f64, f64) {
    let app = micro::analyzed();
    let cfg = ConveyorConfig {
        service: ServiceModel::fixed(5.0),
        misroute_prob: misroute,
        warmup: VTime::from_secs(2),
        horizon: VTime::from_secs(10),
        ..Default::default()
    };
    let r = ConveyorSim::new(
        &app,
        Topology::wan(3),
        ClientsConfig { n: 128, think_ms: 100.0, seed: 9, ..Default::default() },
        cfg,
        |_| Box::new(micro::MicroGenerator::new(&app, 0.8)),
        |_| {},
    )
    .run();
    (r.throughput(), r.mean_latency_ms())
}

fn run_rubis_colocate(p: f64) -> (f64, f64, f64) {
    let app = rubis::analyzed();
    let cfg = ConveyorConfig {
        warmup: VTime::from_secs(2),
        horizon: VTime::from_secs(10),
        ..Default::default()
    };
    let r = ConveyorSim::new(
        &app,
        Topology::wan(3),
        ClientsConfig { n: 512, think_ms: 1000.0, seed: 9, ..Default::default() },
        cfg,
        |g| {
            let mut gen = rubis::RubisGenerator::new(&app, rubis::RubisScale::default())
                .with_stream(g as u64);
            gen.colocate_prob = p;
            Box::new(gen)
        },
        |_| {},
    )
    .run();
    let global_frac = r.metrics.global_latency.count() as f64
        / (r.metrics.global_latency.count() + r.metrics.local_latency.count()).max(1) as f64;
    (r.throughput(), r.mean_latency_ms(), global_frac)
}

fn main() {
    println!("=== Ablation 1: MAP redirects (misrouted clients) ===");
    let rows: Vec<Vec<String>> = [0.0, 0.1, 0.3, 0.5]
        .iter()
        .map(|&p| {
            let (tput, lat) = run_micro(p);
            vec![format!("{:.0}%", p * 100.0), format!("{tput:.0}"), format!("{lat:.1}")]
        })
        .collect();
    println!("{}", report::table(&["misroute", "ops/s", "mean ms"], &rows));

    println!("=== Ablation 2: RUBiS double-key co-location probability ===");
    let rows: Vec<Vec<String>> = [0.0, 0.4, 0.8, 1.0]
        .iter()
        .map(|&p| {
            let (tput, lat, gf) = run_rubis_colocate(p);
            vec![
                format!("{:.0}%", p * 100.0),
                format!("{tput:.0}"),
                format!("{lat:.1}"),
                format!("{:.1}%", gf * 100.0),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(&["co-located", "ops/s", "mean ms", "runtime global"], &rows)
    );

    println!("=== Ablation 3: strict-reads extraction vs paper rule ===");
    for (label, strict) in [("paper (projection only)", false), ("strict (incl. WHERE cols)", true)] {
        let spec = AppSpec {
            name: "tpcw".into(),
            schema: elia::workload::tpcw::full_schema(),
            txns: elia::workload::tpcw::templates(),
        };
        let app = AnalyzedApp::analyze_with(
            spec,
            &PartitionOptions::default(),
            ExtractOptions { strict_reads: strict },
        );
        let (l, g, c, lg, _, _, _) = app.table1_row();
        println!("  {label:<28} TPC-W classes: L={l} G={g} C={c} L/G={lg}");
    }

    println!("\n=== Ablation 4: weighted vs uniform Algorithm-1 cost ===");
    for (label, uniform) in [("frequency weights", false), ("uniform weights", true)] {
        let mut txns = elia::workload::rubis::templates();
        if uniform {
            for t in &mut txns {
                t.weight = 1.0;
            }
        }
        let spec = AppSpec { name: "rubis".into(), schema: elia::workload::rubis::schema(), txns };
        let app = AnalyzedApp::analyze(spec);
        println!(
            "  {label:<22} residual cost = {:.1}  exact={} (choice: {:?})",
            app.partitioning.cost,
            app.partitioning.exact,
            app.partitioning
                .choice
                .iter()
                .take(6)
                .map(|c| c.map(|k| k as i64).unwrap_or(-1))
                .collect::<Vec<_>>()
        );
    }
}
