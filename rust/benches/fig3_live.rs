//! Live counterpart of Figure 3 — a *served* cluster instead of a model.
//!
//! The `fig3` experiment models throughput from measured per-class
//! service times. This bench stands up the real thing: a loopback
//! [`Cluster`](elia::net::Cluster) of 3 servers (framed wire protocol,
//! belt token as ring messages, per-server engines) driven by real
//! client threads through [`NetClient`](elia::net::NetClient), and
//! reports wall-clock throughput, client-observed latency, the
//! local/global/confluent mix the servers actually saw, and the
//! replica-convergence digest at shutdown.
//!
//! Results go to stdout and `BENCH_live.json`. Pass `--quick` for a
//! shorter run (CI uses it).

use elia::harness::experiments::{fig3_live, LivePoint};

fn json_point(p: &LivePoint) -> String {
    let hashes: Vec<String> = p.replica_hashes.iter().map(|h| format!("\"{h:016x}\"")).collect();
    format!(
        concat!(
            "{{\"workload\": \"{}\", \"servers\": {}, \"clients\": {}, \"ops\": {}, ",
            "\"errors\": {}, \"elapsed_s\": {:.4}, \"throughput\": {:.1}, ",
            "\"mean_ms\": {:.4}, \"p99_ms\": {:.4}, \"ops_local\": {}, ",
            "\"ops_global\": {}, \"ops_confluent\": {}, \"client_retries\": {}, ",
            "\"replica_hashes\": [{}], \"converged\": {}}}"
        ),
        p.workload,
        p.servers,
        p.clients,
        p.ops,
        p.errors,
        p.elapsed_s,
        p.throughput,
        p.mean_ms,
        p.p99_ms,
        p.ops_local,
        p.ops_global,
        p.ops_confluent,
        p.client_retries,
        hashes.join(", "),
        p.converged
    )
}

/// Write the measured points as JSON (no serde offline: every field is
/// numeric or a plain identifier, nothing needs escaping).
fn write_json(path: &str, points: &[LivePoint]) {
    let mut s = String::from("{\n  \"points\": [\n");
    for (i, p) in points.iter().enumerate() {
        let sep = if i + 1 < points.len() { "," } else { "" };
        s.push_str(&format!("    {}{sep}\n", json_point(p)));
    }
    s.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write(path, s) {
        eprintln!("warning: could not write {path}: {e}");
    } else {
        println!("[wrote {path}]");
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (clients_axis, ops): (&[usize], u64) =
        if quick { (&[2, 4], 150) } else { (&[1, 2, 4, 8], 400) };
    let t0 = std::time::Instant::now();
    println!("\n=== Figure 3 (live) — served loopback cluster, TPC-W, 3 servers ===");
    let mut points = Vec::new();
    for &clients in clients_axis {
        use elia::harness::experiments::Workload;
        let p = fig3_live(Workload::Tpcw, 3, clients, ops);
        assert!(p.converged, "replicas diverged: {:x?}", p.replica_hashes);
        println!(
            "clients {:>2}: {:>7.0} ops/s  mean {:.2}ms  p99 {:.2}ms  \
             (L {} / G {} / CF {}; {} errors, {} retries, converged)",
            p.clients,
            p.throughput,
            p.mean_ms,
            p.p99_ms,
            p.ops_local,
            p.ops_global,
            p.ops_confluent,
            p.errors,
            p.client_retries,
        );
        points.push(p);
    }
    write_json("BENCH_live.json", &points);
    println!("[fig3_live took {:.2}s]", t0.elapsed().as_secs_f64());
}
