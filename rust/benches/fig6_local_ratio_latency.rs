//! Figure 6 — microbenchmark latency decomposition: mean latency of
//! local vs global operations across local-op ratios, under light load
//! (6a) and heavy load (6b).
//!
//! Expected shape (paper §7.3): local latency is 2-4x below global at
//! every ratio; under light load the overall mean flattens beyond ~70%
//! local, under heavy load it keeps falling past that point.

use elia::harness::experiments::{fig6, ExpScale};
use elia::harness::report;
use elia::simnet::parallel::resolve_threads;
use elia::util::cli::Args;

fn main() {
    let args = Args::from_env();
    // Simulator worker threads; 0 (the default) = all available cores.
    let par = args.get_parse("parallel", 0usize);
    let quick = std::env::var("ELIA_BENCH_QUICK").is_ok();
    let scale =
        (if quick { ExpScale::quick() } else { ExpScale::full() }).with_parallel(par);
    println!("[fig6 simulator threads: {}]", resolve_threads(par));
    let ratios: Vec<f64> = if quick {
        vec![0.3, 0.7]
    } else {
        vec![0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]
    };
    let t0 = std::time::Instant::now();
    for (label, clients) in [("6a: light load", 32), ("6b: heavy load", 512)] {
        println!("\n=== Figure {label} — latency vs local ratio (WAN, 3 servers) ===");
        let rows = fig6(&ratios, clients, &scale);
        let data: Vec<Vec<String>> = rows
            .iter()
            .map(|(r, overall, local, global)| {
                vec![
                    format!("{:.0}%", r * 100.0),
                    format!("{overall:.1}"),
                    format!("{local:.1}"),
                    format!("{global:.1}"),
                    if local.is_nan() || global.is_nan() {
                        "-".into()
                    } else {
                        format!("{:.2}x", global / local)
                    },
                ]
            })
            .collect();
        println!(
            "{}",
            report::table(&["local ratio", "overall ms", "local ms", "global ms", "g/l"], &data)
        );
    }
    println!("[fig6 took {:.1}s]", t0.elapsed().as_secs_f64());
}
