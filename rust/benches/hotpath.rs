//! Hot-path micro-benchmarks (§Perf): per-component cost of the paths
//! that bound end-to-end performance. Hand-rolled timing (criterion is
//! unavailable offline): median of repeated batches.
//!
//! Besides stdout, results are written to `BENCH_hotpath.json`
//! (`name -> ns/op`; the `allocs/op` lines record an allocation count
//! instead of a time) so the perf trajectory is tracked across PRs.
//!
//! The binary runs under a counting global allocator so the borrowed
//! read path's "allocation-free" claim is a measured number, not a code
//! comment: `db: point SELECT allocs/op (borrowed read)` counts heap
//! allocations per executed point SELECT including the value access.

use elia::catalog::{Schema, TableSchema, ValueType};
use elia::db::{BindSlots, Bindings, Db, Value};
use elia::simnet::events::EventQueue;
use elia::sqlir::parse_statement;
use elia::util::{Rng, VTime};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// `System` allocator wrapped with an allocation counter (dealloc is
/// uncounted: the interesting number is how often the hot path asks the
/// allocator for memory at all).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: delegates directly to `System`; the counter has no effect on
// the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

struct Bench {
    results: Vec<(String, f64)>,
}

impl Bench {
    fn run(&mut self, name: &str, iters: u64, mut f: impl FnMut()) -> f64 {
        // Warm up, then take the median of 5 batches.
        for _ in 0..(iters / 10).max(1) {
            f();
        }
        let mut samples = Vec::new();
        for _ in 0..5 {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let per_op = samples[2];
        println!(
            "{name:<46} {:>12.0} ns/op {:>14.0} ops/s",
            per_op * 1e9,
            1.0 / per_op
        );
        self.results.push((name.to_string(), per_op * 1e9));
        per_op
    }

    fn record(&mut self, name: &str, ns: f64) {
        self.results.push((name.to_string(), ns));
    }

    /// Write `name -> ns/op` as JSON (no serde offline: the names contain
    /// no characters that need escaping beyond quotes).
    fn write_json(&self, path: &str) {
        let mut s = String::from("{\n");
        for (i, (name, ns)) in self.results.iter().enumerate() {
            let sep = if i + 1 < self.results.len() { "," } else { "" };
            s.push_str(&format!("  \"{}\": {:.1}{}\n", name.replace('"', "'"), ns, sep));
        }
        s.push_str("}\n");
        match std::fs::write(path, &s) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("\nwarning: could not write {path}: {e}"),
        }
    }
}

fn main() {
    println!("=== hotpath micro-benchmarks ===");
    let mut bench = Bench { results: Vec::new() };

    // --- DB engine: point read / point update / insert ---
    let schema = Schema::new(vec![TableSchema::new(
        "T",
        &[("K", ValueType::Int), ("V", ValueType::Int), ("S", ValueType::Str)],
        &["K"],
    )]);
    let db = Db::new(schema);
    let ins = db.prepare_sql("INSERT INTO T (K, V, S) VALUES (?k, 0, 'x')").unwrap();
    for k in 0..10_000i64 {
        db.exec_auto_prepared(&ins, &BindSlots(vec![Value::Int(k)])).unwrap();
    }
    let sel = db.prepare_sql("SELECT V FROM T WHERE K = ?k").unwrap();
    let upd = db.prepare_sql("UPDATE T SET V = V + 1 WHERE K = ?k").unwrap();
    let mut rng = Rng::new(7);

    bench.run("db: point SELECT (serializable txn)", 50_000, || {
        let slots = BindSlots(vec![Value::Int(rng.range(0, 10_000) as i64)]);
        db.exec_auto_prepared(&sel, &slots).unwrap();
    });
    // The borrowed read path end to end: execute + read the value
    // through the lazy accessor (no Value clones), vs. the explicit
    // to_owned() escape hatch as the owned-materialization reference.
    bench.run("db: point SELECT + scalar read (borrowed)", 50_000, || {
        let slots = BindSlots(vec![Value::Int(rng.range(0, 10_000) as i64)]);
        let r = db.exec_auto_prepared(&sel, &slots).unwrap();
        assert!(r.scalar().is_some());
    });
    bench.run("db: point SELECT + to_owned() (escape hatch)", 50_000, || {
        let slots = BindSlots(vec![Value::Int(rng.range(0, 10_000) as i64)]);
        let r = db.exec_auto_prepared(&sel, &slots).unwrap();
        assert!(!std::hint::black_box(r.to_owned()).is_empty());
    });
    // Allocation count of one borrowed point SELECT (execute + scalar
    // read). The remaining allocations are the point key, the handle
    // vector and the two lock-table entries — zero are value clones;
    // tests/prepared_equivalence.rs asserts the clone count separately.
    {
        let slots = BindSlots(vec![Value::Int(4242)]);
        const N: u64 = 10_000;
        let a0 = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..N {
            let r = db.exec_auto_prepared(&sel, &slots).unwrap();
            assert!(std::hint::black_box(r.scalar()).is_some());
        }
        let per_op = (ALLOCS.load(Ordering::Relaxed) - a0) as f64 / N as f64;
        println!("{:<46} {per_op:>12.1} allocs/op", "db: point SELECT allocs/op (borrowed read)");
        bench.record("db: point SELECT allocs/op (borrowed read)", per_op);
    }
    bench.run("db: point UPDATE (serializable txn)", 50_000, || {
        let slots = BindSlots(vec![Value::Int(rng.range(0, 10_000) as i64)]);
        db.exec_auto_prepared(&upd, &slots).unwrap();
    });
    // The compat path compiles + name-binds per call — kept as a
    // reference line for what prepare-once saves.
    let sel_stmt = parse_statement("SELECT V FROM T WHERE K = ?k").unwrap();
    bench.run("db: point SELECT (unprepared compat path)", 50_000, || {
        let b: Bindings =
            [("k".to_string(), Value::Int(rng.range(0, 10_000) as i64))].into_iter().collect();
        db.exec_auto(&sel_stmt, &b).unwrap();
    });
    bench.run("db: full txn w/ state-update extraction", 20_000, || {
        let slots = BindSlots(vec![Value::Int(rng.range(0, 10_000) as i64)]);
        let mut t = db.begin();
        t.exec_prepared(&upd, &slots).unwrap();
        let u = t.commit().unwrap();
        assert_eq!(u.len(), 1);
    });

    // --- apply_update (replication path) ---
    let update = {
        let mut t = db.begin();
        t.exec_prepared(&upd, &BindSlots(vec![Value::Int(0)])).unwrap();
        t.commit().unwrap()
    };
    bench.run("db: apply_update (1 record)", 50_000, || {
        db.apply_update(&update).unwrap();
    });

    // --- lock manager ---
    let lm = elia::db::LockManager::default();
    let mut txn_id = 1u64;
    bench.run("lockmgr: acquire+release X", 100_000, || {
        use elia::db::lockmgr::{LockMode, LockTarget};
        use elia::db::Key;
        txn_id += 1;
        let target = LockTarget::row(0, &Key::single(Value::Int((txn_id % 512) as i64)));
        lm.acquire(txn_id, target, LockMode::X).unwrap();
        lm.release(txn_id, &[target]);
    });

    // --- simnet event loop ---
    bench.run("simnet: schedule+pop event", 200_000, || {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..8 {
            q.schedule(VTime::from_micros(i), i as u32);
        }
        while q.pop().is_some() {}
    });

    // --- analysis: scalar cost scoring ---
    let app = elia::workload::tpcw::analyzed();
    let tensor = elia::analysis::elim::EliminationTensor::build(&app.spec.txns, &app.matrix);
    let assign: Vec<Option<usize>> = app.partitioning.choice.clone();
    bench.run("analysis: scalar cost(P) on TPC-W tensor", 100_000, || {
        let c = elia::analysis::score::cost(&tensor, &assign);
        assert!(c >= 0.0);
    });

    // --- routing ---
    let op = elia::workload::spec::Operation {
        txn: app.spec.txn_index("doCart").unwrap(),
        args: [("sid".to_string(), Value::Int(42))].into_iter().collect(),
    };
    bench.run("router: route(op) TPC-W doCart", 200_000, || {
        let r = app.route(&op, 8);
        assert!(!matches!(r, elia::workload::analyzed::Route::Any));
    });

    // --- PJRT artifact scoring (if built) ---
    if let Some(eval) = elia::runtime::CostEvaluator::try_default() {
        use elia::analysis::score::BatchScorer;
        let batch: Vec<Vec<Option<usize>>> = (0..256).map(|_| assign.clone()).collect();
        let t0 = Instant::now();
        let mut n = 0;
        while t0.elapsed().as_secs_f64() < 2.0 {
            let v = eval.score(&tensor, &batch);
            assert_eq!(v.len(), 256);
            n += 1;
        }
        let per_exec = t0.elapsed().as_secs_f64() / n as f64;
        println!(
            "{:<46} {:>12.0} ns/cand {:>12.0} cand/s  ({:.2} ms/batch-of-256)",
            "pjrt: artifact batch scoring",
            per_exec / 256.0 * 1e9,
            256.0 / per_exec,
            per_exec * 1e3,
        );
        bench.record("pjrt: artifact batch scoring (ns/cand)", per_exec / 256.0 * 1e9);
    } else {
        println!("pjrt: artifact not built (run `make artifacts`) — skipped");
    }

    // --- end-to-end simulated throughput per wall second ---
    {
        use elia::harness::experiments::{fig6, ExpScale};
        let t0 = Instant::now();
        let rows = fig6(&[0.5], 64, &ExpScale::quick());
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:<46} {:>10.2} s wall (rows={})",
            "sim: fig6 quick point (8s virtual)",
            wall,
            rows.len()
        );
        bench.record("sim: fig6 quick point (wall ns)", wall * 1e9);
    }

    bench.write_json("BENCH_hotpath.json");
}
