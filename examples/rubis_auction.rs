//! RUBiS auction site on a real-threads Eliá deployment: exercises the
//! double-key (local/global) scheme — bids whose user and item live on
//! the same server run locally; cross-server bids go through the token.
//!
//! ```sh
//! cargo run --release --example rubis_auction -- --servers 3 --clients 12 --ops 150
//! ```

use elia::conveyor::{DeployConfig, Deployment};
use elia::db::{Bindings, Value};
use elia::sqlir::parse_statement;
use elia::util::cli::Args;
use elia::util::Rng;
use elia::workload::generator::OpGenerator;
use elia::workload::rubis;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    let n_servers: usize = args.get_parse("servers", 3);
    let n_clients: usize = args.get_parse("clients", 12);
    let ops_per_client: usize = args.get_parse("ops", 150);
    let colocate: f64 = args.get_parse("colocate", 0.8);

    let app = Arc::new(rubis::analyzed());
    let (l, g, c, lg, cf, ro, total) = app.table1_row();
    println!(
        "RUBiS: {total} txns -> {l} L / {g} G / {c} C / {lg} L-G / {cf} CF ({ro} read-only)"
    );
    // Paper Table 1 (11/4/3/8) widened by the invariant-confluence pass:
    // three of the L/G templates run coordination-free.
    assert_eq!((l, g, c, lg, cf), (11, 4, 3, 5, 3), "Table 1 + confluence");

    let scale = rubis::RubisScale { users: 400, items: 800, ..Default::default() };
    let dep = Deployment::start(
        Arc::clone(&app),
        DeployConfig { n_servers, ..Default::default() },
        |db| rubis::seed(db, scale),
    );

    let t0 = Instant::now();
    let errors = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let mut handles = Vec::new();
    for client in 0..n_clients {
        let dep = Arc::clone(&dep);
        let app = Arc::clone(&app);
        let errors = Arc::clone(&errors);
        handles.push(std::thread::spawn(move || {
            let mut gen = rubis::RubisGenerator::new(&app, scale).with_stream(client as u64);
            gen.colocate_prob = colocate;
            let mut rng = Rng::new(1000 + client as u64);
            let site = client % n_servers;
            for _ in 0..ops_per_client {
                let op = gen.next_op(&mut rng, site, n_servers);
                if dep.submit(op).is_err() {
                    errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let local = dep.ops_local.load(Ordering::Relaxed);
    let global = dep.ops_global.load(Ordering::Relaxed);
    println!(
        "ran {} ops in {wall:.2}s ({:.0} ops/s): {local} local, {global} global ({:.1}% global), {} errors",
        local + global,
        (local + global) as f64 / wall,
        100.0 * global as f64 / (local + global) as f64,
        errors.load(Ordering::Relaxed),
    );

    dep.shutdown();
    // Bid conservation: the number of BIDS rows at any server's partition
    // plus replicated global bids must be consistent with the ITEMS
    // counters at that partition (I_NB_BIDS sums).
    let nb = parse_statement("SELECT SUM(I_NB_BIDS) FROM ITEMS").unwrap();
    let bids = parse_statement("SELECT COUNT(*) FROM BIDS").unwrap();
    let mut total_counter = 0i64;
    let mut total_rows = 0i64;
    for s in 0..n_servers {
        let c =
            dep.db(s).exec_auto(&nb, &Bindings::new()).unwrap().scalar().unwrap().as_int().unwrap();
        let r = dep
            .db(s)
            .exec_auto(&bids, &Bindings::new())
            .unwrap()
            .scalar()
            .unwrap()
            .as_int()
            .unwrap();
        total_counter += c;
        total_rows += r;
        println!("  server {s}: SUM(I_NB_BIDS)={c}, BIDS rows={r}");
    }
    // Local bids live at one server; global bids are replicated to all N.
    // Both counters move together inside each storeBid txn, so their
    // totals must be equal.
    assert_eq!(total_counter, total_rows, "bid counters diverged from bid rows");
    println!("bid conservation holds across {n_servers} servers. OK");

    // Show the effect of co-location on the double-key scheme.
    let _ = Value::Int(0);
    println!("(re-run with --colocate 0.0 to see the global share jump)");
}
