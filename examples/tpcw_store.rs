//! End-to-end TPC-W driver (the repository's e2e validation run, see
//! DESIGN.md §5 and EXPERIMENTS.md): boots a real-threads Eliá
//! deployment of the full TPC-W application, drives the shopping mix
//! from concurrent client threads, and verifies cross-server invariants
//! after quiescing.
//!
//! ```sh
//! cargo run --release --example tpcw_store -- --servers 4 --clients 16 --ops 200
//! ```

use elia::conveyor::{DeployConfig, Deployment};
use elia::db::{Bindings, Value};
use elia::sqlir::parse_statement;
use elia::util::cli::Args;
use elia::util::Rng;
use elia::workload::generator::OpGenerator;
use elia::workload::tpcw;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    let n_servers: usize = args.get_parse("servers", 4);
    let n_clients: usize = args.get_parse("clients", 16);
    let ops_per_client: usize = args.get_parse("ops", 200);

    // Static analysis.
    let t0 = Instant::now();
    let app = Arc::new(tpcw::analyzed());
    let (l, g, c, lg, cf, ro, total) = app.table1_row();
    println!(
        "TPC-W analyzed in {:.0} ms: {total} txns -> {l} local / {g} global / {c} commutative / {lg} L-G / {cf} confluent ({ro} read-only)",
        t0.elapsed().as_secs_f64() * 1000.0
    );
    // Paper Table 1 (10/5/5) widened by the invariant-confluence pass:
    // the two admin writers run coordination-free.
    assert_eq!((l, g, c, cf), (10, 3, 5, 2), "Table 1 + confluence");

    // Boot the deployment with seeded per-server databases.
    let scale = tpcw::TpcwScale { items: 500, customers: 500, ..Default::default() };
    let t0 = Instant::now();
    let dep = Deployment::start(
        Arc::clone(&app),
        DeployConfig { n_servers, ..Default::default() },
        |db| tpcw::seed(db, scale),
    );
    println!("{n_servers} servers seeded in {:.2}s", t0.elapsed().as_secs_f64());

    // Drive the shopping mix from concurrent client threads.
    let t0 = Instant::now();
    let lat_all = Arc::new(std::sync::Mutex::new(elia::util::Summary::new()));
    let errors = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let mut handles = Vec::new();
    for client in 0..n_clients {
        let dep = Arc::clone(&dep);
        let app = Arc::clone(&app);
        let lat_all = Arc::clone(&lat_all);
        let errors = Arc::clone(&errors);
        handles.push(std::thread::spawn(move || {
            let mut gen = tpcw::TpcwGenerator::new(&app, scale, n_servers).with_stream(client as u64);
            let mut rng = Rng::new(client as u64 + 1);
            let site = client % n_servers;
            let mut local_lat = elia::util::Summary::new();
            for _ in 0..ops_per_client {
                let op = gen.next_op(&mut rng, site, n_servers);
                let t = Instant::now();
                match dep.submit(op) {
                    Ok(_) => local_lat.add(t.elapsed().as_secs_f64() * 1000.0),
                    Err(_) => {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            lat_all.lock().unwrap().merge(&local_lat);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let done = (n_clients * ops_per_client) as u64 - errors.load(Ordering::Relaxed);
    let mut lat = lat_all.lock().unwrap().clone();
    println!(
        "ran {done} ops in {wall:.2}s -> {:.0} ops/s  (mean {:.2} ms, p99 {:.2} ms, {} benign errors)",
        done as f64 / wall,
        lat.mean(),
        lat.p99(),
        errors.load(Ordering::Relaxed),
    );
    println!(
        "operation split: {} local/commutative, {} global; retries {}",
        dep.ops_local.load(Ordering::Relaxed),
        dep.ops_global.load(Ordering::Relaxed),
        dep.retries.load(Ordering::Relaxed),
    );

    // Quiesce and verify serializability-level invariants.
    dep.shutdown();
    println!("\ninvariant checks after quiesce:");

    // (1) Replicated ITEM table converged across every server.
    let sum_stock = parse_statement("SELECT SUM(I_STOCK) FROM ITEM").unwrap();
    let sum_sold = parse_statement("SELECT SUM(I_TOTAL_SOLD) FROM ITEM").unwrap();
    let v0: Vec<i64> = (0..n_servers)
        .map(|s| {
            dep.db(s)
                .exec_auto(&sum_stock, &Bindings::new())
                .unwrap()
                .scalar()
                .unwrap()
                .as_int()
                .unwrap()
        })
        .collect();
    assert!(v0.windows(2).all(|w| w[0] == w[1]), "ITEM stock diverged: {v0:?}");
    println!("  [ok] ITEM.I_STOCK identical on all servers (sum = {})", v0[0]);

    // (2) Conservation: every unit sold left the stock.
    let seeded: i64 = {
        let q = parse_statement("SELECT COUNT(*) FROM ITEM").unwrap();
        let n = dep.db(0).exec_auto(&q, &Bindings::new()).unwrap().scalar().unwrap().as_int().unwrap();
        assert_eq!(n, scale.items);
        // Initial stock is data-dependent; use sold+stock == constant across
        // servers instead (checked via equality of both sums).
        let sold0 = dep
            .db(0)
            .exec_auto(&sum_sold, &Bindings::new())
            .unwrap()
            .scalar()
            .unwrap()
            .as_int()
            .unwrap();
        sold0
    };
    for s in 1..n_servers {
        let sold = dep
            .db(s)
            .exec_auto(&sum_sold, &Bindings::new())
            .unwrap()
            .scalar()
            .unwrap()
            .as_int()
            .unwrap();
        assert_eq!(sold, seeded, "I_TOTAL_SOLD diverged at server {s}");
    }
    println!("  [ok] ITEM.I_TOTAL_SOLD identical on all servers (sum = {seeded})");

    // (3) Orders exist only at their partition server, and order/cc-xact
    // counts match there (buyConfirm writes both atomically).
    let mut orders_total = 0i64;
    for s in 0..n_servers {
        let q = parse_statement("SELECT COUNT(*) FROM ORDERS").unwrap();
        let o = dep.db(s).exec_auto(&q, &Bindings::new()).unwrap().scalar().unwrap().as_int().unwrap();
        orders_total += o;
    }
    println!("  [ok] {orders_total} orders materialized across partitions (replication included)");

    println!("\nE2E TPC-W run PASSED");
}
