//! Quickstart: run Operation Partitioning end to end on a small
//! application, inspect the classification, and serve a few operations
//! on a real multi-server Conveyor Belt deployment.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use elia::analysis::OpClass;
use elia::catalog::{Schema, TableSchema, ValueType};
use elia::conveyor::{DeployConfig, Deployment};
use elia::db::{Bindings, Value};
use elia::sqlir::parse_statement;
use elia::workload::analyzed::AnalyzedApp;
use elia::workload::spec::{AppSpec, Operation, TxnTemplate};
use std::sync::Arc;

fn main() {
    // 1. Describe the application: schema + transaction templates. This is
    //    the paper's Figure-1 online store: create carts, add items
    //    (stock-checked), order.
    let schema = Schema::new(vec![
        TableSchema::new(
            "CARTS",
            &[("CID", ValueType::Int), ("ITEM", ValueType::Int), ("QTY", ValueType::Int)],
            &["CID", "ITEM"],
        ),
        TableSchema::new(
            "STOCK",
            &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
            &["ITEM"],
        ),
    ]);
    let txns = vec![
        TxnTemplate::new(
            "create",
            &["c"],
            &[("i", "INSERT INTO CARTS (CID, ITEM, QTY) VALUES (?c, 0, 0)")],
            1.0,
        )
        .with_body(|ctx, args| ctx.exec("i", args)),
        TxnTemplate::new(
            "add",
            &["c", "t", "a"],
            &[
                ("check", "SELECT LEVEL FROM STOCK WHERE ITEM = ?t"),
                ("upd", "UPDATE CARTS SET QTY = QTY + ?a WHERE CID = ?c AND ITEM = ?t"),
                ("ins", "INSERT INTO CARTS (CID, ITEM, QTY) VALUES (?c, ?t, ?a)"),
            ],
            3.0,
        )
        .with_body(|ctx, args| {
            let level = ctx.exec("check", args)?;
            if level.scalar().and_then(|v| v.as_int()).unwrap_or(0) <= 0 {
                return Ok(level); // out of stock: no-op reply
            }
            let r = ctx.exec("upd", args)?;
            if r.affected == 0 {
                return ctx.exec("ins", args);
            }
            Ok(r)
        }),
        TxnTemplate::new(
            "order",
            &["c"],
            &[
                ("read", "SELECT ITEM, QTY FROM CARTS WHERE CID = ?c"),
                ("dec", "UPDATE STOCK SET LEVEL = LEVEL - ?q WHERE ITEM = ?derived_item"),
                ("clear", "DELETE FROM CARTS WHERE CID = ?c"),
            ],
            1.0,
        )
        .with_body(|ctx, args| {
            let lines = ctx.exec("read", args)?;
            for line in &lines {
                if line[0].as_int() == Some(0) {
                    continue; // the cart-exists marker row
                }
                let mut b = args.clone();
                b.insert("derived_item".into(), line[0].clone());
                b.insert("q".into(), line[1].clone());
                ctx.exec("dec", &b)?;
            }
            ctx.exec("clear", args)
        }),
    ];
    let spec = AppSpec { name: "store".into(), schema, txns };

    // 2. Static analysis: Algorithm 1 + classification.
    let app = AnalyzedApp::analyze(spec);
    println!("Operation Partitioning results for '{}':", app.spec.name);
    for (t, tpl) in app.spec.txns.iter().enumerate() {
        let routing: Vec<&str> = app.classification.routing_params[t]
            .iter()
            .map(|&k| tpl.params[k].as_str())
            .collect();
        println!(
            "  {:<8} -> {:?} (routes by {:?})",
            tpl.name,
            app.class(t),
            routing
        );
    }
    assert_eq!(*app.class(0), OpClass::Local);
    assert_eq!(*app.class(2), OpClass::Global);

    // 3. Boot a 3-server deployment (real threads, real DBMS instances).
    let app = Arc::new(app);
    let dep = Deployment::start(Arc::clone(&app), DeployConfig::default(), |db| {
        let ins = parse_statement("INSERT INTO STOCK (ITEM, LEVEL) VALUES (?i, 100)").unwrap();
        for i in 1..=20i64 {
            let b: Bindings = [("i".to_string(), Value::Int(i))].into_iter().collect();
            db.exec_auto(&ins, &b).unwrap();
        }
    });

    // 4. Run a few client operations: create a cart, add items, order.
    let op = |txn: &str, pairs: Vec<(&str, i64)>| Operation {
        txn: app.spec.txn_index(txn).unwrap(),
        args: pairs.into_iter().map(|(k, v)| (k.to_string(), Value::Int(v))).collect(),
    };
    for cart in 0..6i64 {
        dep.submit(op("create", vec![("c", cart)])).unwrap();
        dep.submit(op("add", vec![("c", cart), ("t", 1 + cart % 20), ("a", 2)])).unwrap();
        dep.submit(op("add", vec![("c", cart), ("t", 7), ("a", 1)])).unwrap();
        dep.submit(op("order", vec![("c", cart)])).unwrap();
    }
    println!(
        "\nserved {} local + {} global operations on {} servers",
        dep.ops_local.load(std::sync::atomic::Ordering::Relaxed),
        dep.ops_global.load(std::sync::atomic::Ordering::Relaxed),
        dep.n_servers()
    );

    // 5. Quiesce and verify: the replicated STOCK table converged at every
    //    server, and exactly 6*(2+1) units were sold.
    dep.shutdown();
    let q = parse_statement("SELECT SUM(LEVEL) FROM STOCK").unwrap();
    let expect = 20 * 100 - 6 * 3;
    for s in 0..dep.n_servers() {
        let total = dep
            .db(s)
            .exec_auto(&q, &Bindings::new())
            .unwrap()
            .scalar()
            .unwrap()
            .as_int()
            .unwrap();
        assert_eq!(total, expect, "server {s} diverged");
    }
    println!("replicated stock converged on all servers (sum = {expect}). OK");
}
