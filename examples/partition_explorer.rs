//! Partition explorer: inspect what Operation Partitioning's static
//! analysis finds for TPC-W or RUBiS — read/write sets, pairwise
//! conflicts, the optimized partitioning array and the classification —
//! and compare the scalar scorer against the AOT Pallas artifact.
//!
//! ```sh
//! cargo run --release --example partition_explorer -- --workload tpcw
//! ```

use elia::analysis::elim::EliminationTensor;
use elia::analysis::score::{cost, BatchScorer, ScalarScorer};
use elia::harness::experiments::Workload;
use elia::runtime::CostEvaluator;
use elia::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let workload = match args.get_or("workload", "tpcw") {
        "rubis" => Workload::Rubis,
        _ => Workload::Tpcw,
    };
    let app = workload.analyzed();

    println!("== {} analysis ==", workload.name());
    println!("{} transactions, {} tables\n", app.spec.txns.len(), app.spec.schema.ntables());

    println!("-- read/write sets --");
    for (tpl, rw) in app.spec.txns.iter().zip(&app.rwsets) {
        println!("  {:<22} {} read entries, {} write entries", tpl.name, rw.reads.len(), rw.writes.len());
    }

    let tensor = EliminationTensor::build(&app.spec.txns, &app.matrix);
    println!("\n-- conflict structure --");
    println!("  {} conflicting transaction pairs", tensor.conflict_pairs());
    println!("  {} connected components", tensor.components().len());

    println!("\n-- optimized partitioning (Algorithm 1) --");
    println!("  residual cost: {:.1} (exact search: {})", app.partitioning.cost, app.partitioning.exact);
    for (t, tpl) in app.spec.txns.iter().enumerate() {
        let choice = app.partitioning.choice[t]
            .map(|k| tpl.params[k].clone())
            .unwrap_or_else(|| "-".into());
        let routing: Vec<&str> = app.classification.routing_params[t]
            .iter()
            .map(|&k| tpl.params[k].as_str())
            .collect();
        println!(
            "  {:<22} {:<12} partition by {:<8} route by {:?}",
            tpl.name,
            format!("{:?}", app.class(t)),
            choice,
            routing
        );
    }

    // Cross-check the scalar scorer against the AOT artifact.
    println!("\n-- scorer cross-check (scalar vs PJRT/Pallas artifact) --");
    let assign = app.partitioning.choice.clone();
    let scalar = cost(&tensor, &assign);
    println!("  scalar cost(P*) = {scalar:.3}");
    match CostEvaluator::try_default() {
        Some(eval) => {
            let accel = eval.score(&tensor, &[assign.clone()])[0];
            println!("  artifact cost(P*) = {accel:.3} (platform {})", eval.platform());
            assert!((scalar - accel).abs() < 1e-3, "scorers disagree!");
            // Micro-parity on random assignments.
            let mut rng = elia::util::Rng::new(1);
            let batch: Vec<Vec<Option<usize>>> = (0..64)
                .map(|_| {
                    tensor
                        .kdims
                        .iter()
                        .map(|&k| if k == 0 { None } else { Some(rng.range(0, k)) })
                        .collect()
                })
                .collect();
            let s = ScalarScorer.score(&tensor, &batch);
            let a = eval.score(&tensor, &batch);
            let max_err = s
                .iter()
                .zip(&a)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f64, f64::max);
            println!("  64 random assignments: max |scalar - artifact| = {max_err:.2e}");
        }
        None => println!("  artifact not built; run `make artifacts` first"),
    }
}
