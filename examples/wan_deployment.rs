//! WAN deployment study (simulated): reproduce the paper's RQ2 story for
//! one configuration from your terminal — centralized vs read-only vs
//! Eliá at N geo-distributed sites, with Table 2 latencies.
//!
//! ```sh
//! cargo run --release --example wan_deployment -- --sites 5 --workload rubis
//! ```

use elia::harness::experiments::{fig4, table3, ExpScale, Workload};
use elia::harness::report;
use elia::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let sites: usize = args.get_parse("sites", 5);
    let workload = match args.get_or("workload", "tpcw") {
        "rubis" => Workload::Rubis,
        _ => Workload::Tpcw,
    };
    let scale = if args.has("full") { ExpScale::full() } else { ExpScale::quick() };

    println!("== light-load latency (Table 3 shape), {} ==", workload.name());
    let rows = table3(workload, &scale);
    let cen = rows.iter().find(|(l, _)| l == "centralized").map(|(_, v)| *v).unwrap();
    let data: Vec<Vec<String>> = rows
        .iter()
        .map(|(l, ms)| {
            vec![
                l.clone(),
                format!("{ms:.0}ms"),
                if l == "centralized" { "-".into() } else { format!("{:.1}x", cen / ms) },
            ]
        })
        .collect();
    println!("{}", report::table(&["config", "latency", "speedup"], &data));

    println!("\n== load curves at {sites} sites (Figure 4 shape) ==");
    let curves = fig4(workload, sites, &scale);
    println!("{}", report::curves_table(&curves));
    for c in &curves {
        if let Some(p) = c.peak(5000.0) {
            println!("  {}: sustains {:.0} ops/s", c.label, p.throughput);
        }
    }
}
