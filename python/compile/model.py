"""L2 — the JAX compute graph AOT-compiled for the Rust coordinator.

This paper's "model" is not a neural network: the dense computation the
coordinator needs at analysis time is batched partition-cost scoring
(Algorithm 1's optimization phase). The graph wraps the L1 Pallas kernel
(`kernels.partition_cost`) at fixed padded shapes and is lowered once by
`aot.py` to HLO text that `rust/src/runtime` loads via PJRT.

Shape contract (must match `rust/src/runtime/mod.rs` constants):

    B = 256   candidate batch
    T = 32    max transactions (padded)
    K = 8     max parameters per transaction (padded)

    partition_cost_model : (cand f32[B,T,K], cw f32[T,T], elim f32[T,T,K,K])
                           -> (cost f32[B],)

Padding rows/planes are all-zero and contribute exactly 0 to the cost, so
the Rust side can embed any application with T ≤ 32, K ≤ 8.
"""

import jax.numpy as jnp

from .kernels.partition_cost import partition_cost

# The AOT shape contract. Keep in sync with rust/src/runtime/mod.rs.
AOT_B = 256
AOT_T = 32
AOT_K = 8


def partition_cost_model(cand, cw, elim):
    """The exported computation (1-tuple result, see aot.py)."""
    assert cand.shape == (AOT_B, AOT_T, AOT_K), cand.shape
    assert cw.shape == (AOT_T, AOT_T), cw.shape
    assert elim.shape == (AOT_T, AOT_T, AOT_K, AOT_K), elim.shape
    return (partition_cost(cand, cw, elim),)


def example_args():
    """ShapeDtypeStructs for lowering."""
    import jax

    return (
        jax.ShapeDtypeStruct((AOT_B, AOT_T, AOT_K), jnp.float32),
        jax.ShapeDtypeStruct((AOT_T, AOT_T), jnp.float32),
        jax.ShapeDtypeStruct((AOT_T, AOT_T, AOT_K, AOT_K), jnp.float32),
    )
