"""L1 — the partition-cost Pallas kernel.

The hot spot of Operation Partitioning is scoring batches of candidate
partitioning arrays against the conflict structure (Algorithm 1's cost
function, evaluated for every point of the exhaustive search). We recast
it as a quadratic form so the contraction runs on the MXU:

    C = cand.reshape(B, T*K)                       # one-hot rows
    W[t*K+k, t'*K+k'] = cw[t,t'] * elim[t,t',k,k'] # "covered weight"
    q[b]    = C[b] @ W @ C[b]^T                    # eliminated weight
    cost[b] = sum(cw) - q[b]

The kernel computes ``q`` tiled over the batch dimension: each grid step
loads a ``[BB, TK]`` candidate block and the full ``[TK, TK]`` W matrix
into VMEM, performs one ``[BB,TK] @ [TK,TK]`` matmul (MXU) and a
row-reduction (VPU).

TPU sizing (DESIGN.md §Hardware-Adaptation): at the AOT shapes
``B=256, T=32, K=8`` → ``TK=256, BB=128``; per-step VMEM =
C-block 128·256·4 = 128 KiB + W 256·256·4 = 256 KiB + out 0.5 KiB
≈ 385 KiB, far under the ~16 MiB budget; W stays resident across both
grid steps. The matmul is 128×256×256 — MXU-shaped (multiples of the
128×128 systolic tile).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the artifact runs on
the Rust CPU client. Real-TPU numbers are estimated, not measured.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile. 128 keeps the MXU busy and two buffers under VMEM budget.
DEFAULT_BLOCK_B = 128


def _qform_kernel(c_ref, w_ref, o_ref):
    """o[b] = sum_j (C @ W)[b, j] * C[b, j] for one batch tile."""
    c = c_ref[...]  # [BB, TK]
    w = w_ref[...]  # [TK, TK]
    cw = jnp.dot(c, w, preferred_element_type=jnp.float32)  # MXU
    o_ref[...] = jnp.sum(cw * c, axis=-1)  # VPU row-reduce


def _quadratic_form(c, w, *, block_b):
    """q[b] = C[b] @ W @ C[b]^T via a batch-tiled Pallas kernel."""
    bdim, tk = c.shape
    assert w.shape == (tk, tk), (c.shape, w.shape)
    # Pad the batch up to a multiple of the tile.
    pad = (-bdim) % block_b
    if pad:
        c = jnp.pad(c, ((0, pad), (0, 0)))
    padded_b = c.shape[0]
    grid = (padded_b // block_b,)
    q = pl.pallas_call(
        _qform_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, tk), lambda i: (i, 0)),  # stream C tiles
            pl.BlockSpec((tk, tk), lambda i: (0, 0)),  # W resident
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded_b,), jnp.float32),
        interpret=True,  # CPU-PJRT compatible lowering (see module docstring)
    )(c, w)
    return q[:bdim]


@functools.partial(jax.jit, static_argnames=("block_b",))
def partition_cost(cand, cw, elim, *, block_b=DEFAULT_BLOCK_B):
    """Batched Algorithm-1 cost via the Pallas quadratic-form kernel.

    Args:
      cand: f32[B, T, K] one-hot candidate partitioning arrays.
      cw:   f32[T, T] conflict-weight matrix (upper triangle).
      elim: f32[T, T, K, K] coverage bits.
      block_b: batch tile size (static).

    Returns:
      f32[B] costs, identical to ``ref.partition_cost_ref``.
    """
    b, t, k = cand.shape
    tk = t * k
    c = cand.reshape(b, tk)
    # W[t*K+k, t'*K+k'] = cw[t,t'] * elim[t,t',k,k']
    w = (cw[:, :, None, None] * elim).transpose(0, 2, 1, 3).reshape(tk, tk)
    total = jnp.sum(cw)
    q = _quadratic_form(c, w, block_b=min(block_b, max(b, 1)))
    return total - q


@jax.jit
def hypergraph_cost(cand, w, conflict, elim):
    """Batched hypergraph-cut cost, mirroring the Rust drift scorer.

    The pairwise ``partition_cost`` charges every surviving conflicting
    *pair*; this charges each *template* hyperedge once, as soon as any
    incident conflict survives the assignment — the cost the epoch
    controller minimizes (``HypergraphScorer::cut`` in
    ``rust/src/analysis/hypergraph.rs``):

        cost[b] = sum_t w[t] * [exists t': conflict(t,t') and not
                                covered under (cand[b,t], cand[b,t'])]

    ``conflict`` and ``elim`` are populated on the upper triangle only
    (like ``cw``); access is normalized onto it, and an all-zero
    candidate row ("no parameter") never covers anything.

    Args:
      cand:     f32[B, T, K] one-hot candidate partitioning arrays.
      w:        f32[T] per-template hyperedge weights (observed rates).
      conflict: f32[T, T] 0/1 conflict adjacency (upper triangle).
      elim:     f32[T, T, K, K] coverage bits (upper triangle).

    Returns:
      f32[B] costs.
    """
    _, t, _ = cand.shape
    covered = jnp.einsum("btk,bsl,tskl->bts", cand, cand, elim)
    iu = jnp.triu(jnp.ones((t, t), cand.dtype))
    cov = iu[None] * covered + (1.0 - iu)[None] * jnp.swapaxes(covered, 1, 2)
    link = iu * conflict + (1.0 - iu) * conflict.T
    # broken[b,t] = 1 iff any incident conflict survives (bits, so max = any).
    broken = jnp.max(link[None] * (1.0 - cov), axis=2)
    return jnp.sum(w[None, :] * broken, axis=1)
