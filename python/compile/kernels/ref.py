"""Pure-jnp oracle for the partition-cost kernel.

This is the CORE correctness signal for L1: ``partition_cost`` (the Pallas
kernel) must match this einsum formulation bit-for-bit on integer-valued
weights and to float tolerance otherwise.

Semantics (Algorithm 1, cost function): for a batch of candidate
operation-partitioning arrays encoded one-hot,

    covered[b,t,t'] = sum_{k,k'} cand[b,t,k] * cand[b,t',k'] * elim[t,t',k,k']
    cost[b]         = sum_{t,t'} cw[t,t'] * (1 - covered[b,t,t'])

``cw[t,t'] = conflict[t,t'] * (weight(t) + weight(t'))`` is populated only
on the upper triangle by the Rust exporter, so each unordered conflict is
counted exactly once.
"""

import jax.numpy as jnp


def partition_cost_ref(cand, cw, elim):
    """Reference implementation.

    Args:
      cand: f32[B, T, K] one-hot (rows may be all-zero = "no parameter").
      cw:   f32[T, T] conflict-weight matrix (upper triangle).
      elim: f32[T, T, K, K] coverage bits.

    Returns:
      f32[B] costs.
    """
    covered = jnp.einsum("btk,bsl,tskl->bts", cand, cand, elim)
    return jnp.sum(cw[None, :, :] * (1.0 - covered), axis=(1, 2))
