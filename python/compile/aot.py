"""AOT export: lower the L2 graph (with its L1 Pallas kernel) to HLO text.

HLO *text* — not serialized HloModuleProto — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot [--out ../artifacts/partition_cost.hlo.txt]
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import example_args, partition_cost_model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(out_path: str) -> int:
    lowered = jax.jit(partition_cost_model).lower(*example_args())
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out",
        default=os.path.join(
            os.path.dirname(__file__), "..", "..", "artifacts", "partition_cost.hlo.txt"
        ),
    )
    args = ap.parse_args()
    n = export(args.out)
    print(f"wrote {n} chars to {args.out}")


if __name__ == "__main__":
    main()
