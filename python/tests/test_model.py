"""L2 correctness: the AOT-shaped model graph vs the oracle, and the
padding contract the Rust runtime relies on."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import partition_cost_ref
from compile.model import AOT_B, AOT_K, AOT_T, example_args, partition_cost_model


def embed(cand_small, cw_small, elim_small):
    """Embed a small instance into the padded AOT shapes."""
    b, t, k = cand_small.shape
    cand = np.zeros((AOT_B, AOT_T, AOT_K), np.float32)
    cand[:b, :t, :k] = cand_small
    cw = np.zeros((AOT_T, AOT_T), np.float32)
    cw[:t, :t] = cw_small
    elim = np.zeros((AOT_T, AOT_T, AOT_K, AOT_K), np.float32)
    elim[:t, :t, :k, :k] = elim_small
    return jnp.asarray(cand), jnp.asarray(cw), jnp.asarray(elim)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_model_matches_ref_at_aot_shapes(seed):
    rng = np.random.default_rng(seed)
    from tests.test_kernel import make_instance

    small = make_instance(rng, 32, 10, 4)
    cand, cw, elim = embed(*(np.asarray(x) for x in small))
    (got,) = partition_cost_model(cand, cw, elim)
    want = partition_cost_ref(cand, cw, elim)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-5)


def test_padding_contributes_zero():
    # A tiny instance embedded in padding must cost exactly what the
    # unpadded oracle says.
    rng = np.random.default_rng(3)
    from tests.test_kernel import make_instance

    small = make_instance(rng, 8, 3, 2)
    want_small = np.asarray(partition_cost_ref(*small))
    cand, cw, elim = embed(*(np.asarray(x) for x in small))
    (got,) = partition_cost_model(cand, cw, elim)
    np.testing.assert_allclose(np.asarray(got)[:8], want_small, rtol=1e-6, atol=1e-6)
    # Padded batch rows (all-zero candidates) each pay the full conflict
    # weight (nothing covered).
    np.testing.assert_allclose(np.asarray(got)[8:], float(np.sum(np.asarray(cw))), rtol=1e-6)


def test_example_args_match_contract():
    a, b, c = example_args()
    assert a.shape == (AOT_B, AOT_T, AOT_K)
    assert b.shape == (AOT_T, AOT_T)
    assert c.shape == (AOT_T, AOT_T, AOT_K, AOT_K)
    assert all(x.dtype == jnp.float32 for x in (a, b, c))
