"""AOT export smoke: the HLO text artifact is produced and looks like an
HLO module with the agreed entry signature."""

import os

from compile.aot import export


def test_export_writes_hlo_text(tmp_path):
    out = tmp_path / "partition_cost.hlo.txt"
    n = export(str(out))
    assert n > 1000
    text = out.read_text()
    assert text.startswith("HloModule")
    # Three parameters at the padded shapes, f32 output tuple.
    assert "f32[256,32,8]" in text
    assert "f32[32,32]" in text
    assert "f32[32,32,8,8]" in text
    assert "f32[256]" in text


def test_export_is_deterministic(tmp_path):
    a = tmp_path / "a.hlo.txt"
    b = tmp_path / "b.hlo.txt"
    export(str(a))
    export(str(b))
    assert a.read_text() == b.read_text()


def test_export_creates_directories(tmp_path):
    out = tmp_path / "deep" / "nested" / "x.hlo.txt"
    export(str(out))
    assert os.path.exists(out)
