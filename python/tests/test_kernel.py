"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and random one-hot structures; costs are
integer-valued when weights are integers, so most comparisons are exact.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.partition_cost import hypergraph_cost, partition_cost
from compile.kernels.ref import partition_cost_ref


def make_instance(rng, b, t, k, *, int_weights=True, hole_prob=0.2):
    """Random (cand, cw, elim) instance with valid structure."""
    # One-hot candidates with some all-zero rows ("no parameter").
    cand = np.zeros((b, t, k), np.float32)
    for bi in range(b):
        for ti in range(t):
            if rng.random() > hole_prob:
                cand[bi, ti, rng.integers(k)] = 1.0
    # Upper-triangular conflict weights.
    cw = np.zeros((t, t), np.float32)
    for i in range(t):
        for j in range(i, t):
            if rng.random() < 0.5:
                cw[i, j] = (
                    float(rng.integers(1, 10)) if int_weights else float(rng.random() * 10)
                )
    elim = (rng.random((t, t, k, k)) < 0.3).astype(np.float32)
    return jnp.asarray(cand), jnp.asarray(cw), jnp.asarray(elim)


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 40),
    t=st.integers(1, 8),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31),
    block=st.sampled_from([2, 8, 128]),
)
def test_kernel_matches_ref_random_shapes(b, t, k, seed, block):
    rng = np.random.default_rng(seed)
    cand, cw, elim = make_instance(rng, b, t, k)
    got = partition_cost(cand, cw, elim, block_b=block)
    want = partition_cost_ref(cand, cw, elim)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_integer_weights_are_exact(seed):
    rng = np.random.default_rng(seed)
    cand, cw, elim = make_instance(rng, 16, 6, 3, int_weights=True)
    got = np.asarray(partition_cost(cand, cw, elim, block_b=8))
    want = np.asarray(partition_cost_ref(cand, cw, elim))
    # All values are small integer sums: must match exactly.
    assert np.array_equal(got, want)
    assert np.allclose(got, np.round(got))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_float_weights_close(seed):
    rng = np.random.default_rng(seed)
    cand, cw, elim = make_instance(rng, 8, 5, 3, int_weights=False)
    got = partition_cost(cand, cw, elim, block_b=8)
    want = partition_cost_ref(cand, cw, elim)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_no_conflicts_costs_zero():
    cand = jnp.zeros((4, 3, 2), jnp.float32)
    cw = jnp.zeros((3, 3), jnp.float32)
    elim = jnp.zeros((3, 3, 2, 2), jnp.float32)
    out = np.asarray(partition_cost(cand, cw, elim))
    assert np.array_equal(out, np.zeros(4, np.float32))


def test_full_elimination_costs_zero():
    # Everything conflicts but every choice eliminates: cost 0.
    b, t, k = 5, 4, 2
    rng = np.random.default_rng(0)
    cand = np.zeros((b, t, k), np.float32)
    for bi in range(b):
        for ti in range(t):
            cand[bi, ti, rng.integers(k)] = 1.0
    cw = np.triu(np.ones((t, t), np.float32))
    elim = np.ones((t, t, k, k), np.float32)
    out = np.asarray(partition_cost(jnp.asarray(cand), jnp.asarray(cw), jnp.asarray(elim)))
    np.testing.assert_allclose(out, 0.0, atol=1e-6)


def test_no_choice_pays_full_weight():
    # All-zero candidates: nothing covered, cost = sum(cw).
    b, t, k = 3, 4, 2
    cand = jnp.zeros((b, t, k), jnp.float32)
    cw = jnp.triu(jnp.ones((t, t), jnp.float32) * 2.0)
    elim = jnp.ones((t, t, k, k), jnp.float32)
    out = np.asarray(partition_cost(cand, cw, elim))
    np.testing.assert_allclose(out, float(np.sum(np.triu(np.ones((t, t)) * 2.0))))


def test_paper_cart_example():
    # createCart(sid) / doCart(sid, iid, q): partitioning both on sid
    # eliminates all three conflicts; doCart on iid leaves the cross pair.
    t, k = 2, 3
    cw = np.zeros((t, t), np.float32)
    cw[0, 0] = 2.0  # create-create, w=1+1
    cw[0, 1] = 3.0  # create-doCart, w=1+2
    cw[1, 1] = 4.0  # doCart-doCart, w=2+2
    elim = np.zeros((t, t, k, k), np.float32)
    elim[0, 0, 0, 0] = 1.0  # (sid, sid)
    elim[0, 1, 0, 0] = 1.0  # create.sid vs doCart.sid (param 0)
    elim[1, 1, 0, 0] = 1.0  # doCart self: sid=sid'
    elim[1, 1, 1, 1] = 1.0  # doCart self also covered by iid=iid'
    cand = np.zeros((3, t, k), np.float32)
    cand[0, 0, 0] = cand[0, 1, 0] = 1.0  # both sid  -> cost 0
    cand[1, 0, 0] = cand[1, 1, 1] = 1.0  # doCart=iid -> pays 3.0
    # candidate 2: no params at all     -> pays 9.0
    out = np.asarray(partition_cost(jnp.asarray(cand), jnp.asarray(cw), jnp.asarray(elim)))
    np.testing.assert_allclose(out, [0.0, 3.0, 9.0])


@pytest.mark.parametrize("block", [1, 3, 64, 128, 256])
def test_block_size_invariance(block):
    rng = np.random.default_rng(7)
    cand, cw, elim = make_instance(rng, 37, 6, 4)
    base = np.asarray(partition_cost(cand, cw, elim, block_b=128))
    got = np.asarray(partition_cost(cand, cw, elim, block_b=block))
    np.testing.assert_allclose(got, base, rtol=1e-6)


def hypergraph_cost_oracle(cand, w, conflict, elim):
    """Loop transcription of HypergraphScorer::cut (rust hypergraph.rs)."""
    b, t, k = cand.shape
    out = np.zeros(b, np.float32)
    for bi in range(b):
        for ti in range(t):
            broken = False
            for si in range(t):
                a, c = (ti, si) if ti <= si else (si, ti)
                if not conflict[a, c]:
                    continue
                ka, kc = np.argmax(cand[bi, a]), np.argmax(cand[bi, c])
                covered = (
                    cand[bi, a].any()
                    and cand[bi, c].any()
                    and elim[a, c, ka, kc] > 0.0
                )
                if not covered:
                    broken = True
                    break
            if broken:
                out[bi] += w[ti]
    return out


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 24),
    t=st.integers(1, 8),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_hypergraph_matches_rust_oracle(b, t, k, seed):
    rng = np.random.default_rng(seed)
    cand, cw, elim = make_instance(rng, b, t, k)
    conflict = (np.asarray(cw) > 0).astype(np.float32)
    w = rng.integers(1, 10, t).astype(np.float32)
    got = np.asarray(hypergraph_cost(cand, jnp.asarray(w), jnp.asarray(conflict), elim))
    want = hypergraph_cost_oracle(np.asarray(cand), w, conflict, np.asarray(elim))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)


def test_hypergraph_cart_example():
    # Mirrors hypergraph.rs::each_broken_template_pays_once: both on sid
    # covers everything; doCart on iid breaks the cross pair, so BOTH
    # hyperedges pay — but each exactly once (3.0, not the pairwise 3.0+).
    t, k = 2, 3
    conflict = np.zeros((t, t), np.float32)
    conflict[0, 0] = conflict[0, 1] = conflict[1, 1] = 1.0
    elim = np.zeros((t, t, k, k), np.float32)
    elim[0, 0, 0, 0] = 1.0
    elim[0, 1, 0, 0] = 1.0
    elim[1, 1, 0, 0] = 1.0
    elim[1, 1, 1, 1] = 1.0
    w = np.array([1.0, 2.0], np.float32)
    cand = np.zeros((3, t, k), np.float32)
    cand[0, 0, 0] = cand[0, 1, 0] = 1.0  # both sid   -> 0.0
    cand[1, 0, 0] = cand[1, 1, 1] = 1.0  # doCart=iid -> 1.0 + 2.0
    # candidate 2: no params at all      -> 3.0
    out = np.asarray(
        hypergraph_cost(
            jnp.asarray(cand), jnp.asarray(w), jnp.asarray(conflict), jnp.asarray(elim)
        )
    )
    np.testing.assert_allclose(out, [0.0, 3.0, 3.0])
